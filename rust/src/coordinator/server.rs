//! Batched inference service: the router/batcher pattern (vLLM-style)
//! over the unified [`Query`] API.
//!
//! Clients submit typed [`Query`] values (evidence row + query) through
//! [`InferenceServer::submit_query`] — or through the legacy shims
//! ([`InferenceServer::submit`] = `Marginal`,
//! [`InferenceServer::submit_generate`] = `Inpaint`,
//! [`InferenceServer::submit_mpe`] = `Mpe`). A dispatcher thread
//! coalesces up to `max_batch` pending requests (or whatever has arrived
//! within `max_wait`), compiles each into a [`QueryPlan`] once, groups
//! requests whose compiled plans are identical
//! ([`QueryPlan::group_cmp`]), and serves each group with the plan's
//! semiring-parameterized forward passes plus (for decoding queries) ONE
//! batched top-down decode. Because grouping is by *compiled plan*, a
//! marginal, a conditional, a max-product MPE, and an inpainting request
//! each land in their own batch automatically — no parallel bespoke
//! request types.
//!
//! The dispatcher is backend-agnostic: a private engine of any type
//! implementing [`Engine`] ([`InferenceServer::start`]), a backend picked
//! by name from the runtime registry ([`InferenceServer::start_named`]),
//! or a scope-partitioned [`ShardedPool`]
//! ([`InferenceServer::start_sharded`]) whose segment workers each hold
//! only their parameter shard. MPE serves sharded for free: the
//! max-product forward crosses the cut through the same boundary
//! activation rows as sum-product, and the backtrack through the same
//! one-`sel`-u32-per-region·sample tables as sampling. Batches are
//! handed to the sharded backend as a shared `Arc` (no per-call copy).

use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use super::ShardedPool;
use crate::engine::query::{Query, QueryOutput, QueryPlan};
use crate::engine::registry::{EngineFactory, EngineRegistry};
use crate::engine::{DecodeMode, EinetParams, Engine};
use crate::layers::LayeredPlan;
use crate::leaves::LeafFamily;
use crate::util::error::Result;
use crate::util::rng::Rng;

/// What the dispatcher executes batches on: one private engine, or a
/// scope-partitioned worker pool ([`ShardedPool`]) for models larger than
/// one core's cache. Both present the same two calls the router needs.
enum Backend {
    /// a private engine plus the one resident parameter arena
    Single(Box<dyn Engine + Send>, EinetParams),
    /// the pool owns the master arena (workers hold only their shards),
    /// so no second full copy lives on the serving host
    Sharded(ShardedPool),
}

impl Backend {
    /// Serve one plan-homogeneous group. The single-engine case IS
    /// [`Engine::execute`] — one source of truth for how a compiled plan
    /// runs; the sharded case replays the same plan semantics over the
    /// pool's segmented primitives (which have no boxed-engine `execute`),
    /// shipping the batch `Arc` to the workers with no per-call copy.
    fn run_plan(
        &mut self,
        qp: &QueryPlan,
        x: &Arc<Vec<f32>>,
        bn: usize,
        rng: &mut Rng,
        den: &mut Vec<f32>,
        out: &mut QueryOutput,
    ) {
        match self {
            Backend::Single(e, params) => e.execute(params, qp, x.as_slice(), bn, rng, out),
            Backend::Sharded(p) => {
                out.scores.clear();
                out.scores.resize(bn, 0.0);
                out.rows.clear();
                let m0 = Arc::new(qp.passes[0].mask.clone());
                p.forward_shared(
                    x.clone(),
                    0,
                    m0.clone(),
                    bn,
                    qp.passes[0].semiring,
                    &mut out.scores,
                );
                if let Some(mode) = qp.decode {
                    out.rows.extend_from_slice(x.as_slice());
                    p.decode(bn, m0.as_slice(), mode, rng, &mut out.rows);
                }
                if qp.is_ratio() {
                    den.clear();
                    den.resize(bn, 0.0);
                    let m1 = Arc::new(qp.passes[1].mask.clone());
                    p.forward_shared(x.clone(), 0, m1, bn, qp.passes[1].semiring, den);
                    for b in 0..bn {
                        out.scores[b] -= den[b];
                    }
                }
            }
        }
    }
}

/// A served answer: the per-row log score (marginal / conditional /
/// max-product MPE, depending on the query) plus, for decoding queries,
/// the completed `[D, obs_dim]` row (observed dims untouched).
#[derive(Clone, Debug)]
pub struct QueryAnswer {
    pub score: f32,
    /// empty for score-only queries
    pub row: Vec<f32>,
}

/// How a request wants its answer delivered: the legacy endpoints keep
/// their scalar/row channel types, the unified endpoint gets everything.
enum ReplyTo {
    Score(Sender<f32>),
    Row(Sender<Vec<f32>>),
    Full(Sender<QueryAnswer>),
}

/// One in-flight request: evidence row + typed query + reply channel.
struct QueryRequest {
    x: Vec<f32>,
    query: Query,
    reply: ReplyTo,
}

/// Handle to the running service.
pub struct InferenceServer {
    tx: Sender<QueryRequest>,
    handle: Option<JoinHandle<ServerStats>>,
}

/// Throughput accounting returned on shutdown.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServerStats {
    /// score-only queries served (LogLik / Marginal / Conditional)
    pub queries: usize,
    pub batches: usize,
    /// decoded rows produced (Inpaint / Mpe)
    pub generated: usize,
    /// malformed requests dropped at the dispatch boundary (wrong-length
    /// evidence/mask, non-finite mask values, overlapping conditional
    /// masks, observed evidence outside the leaf family's support, or a
    /// `Sample` query — unsupported per-request here)
    pub rejected: usize,
    /// largest number of requests served by a single batched pass — the
    /// coalescing witness the tests assert on (>= 2 proves batching
    /// without depending on wall-clock wave counts)
    pub max_group: usize,
}

impl InferenceServer {
    /// Spawn the dispatcher with its private engine of type `E` (sampler
    /// seeded with 0; use [`InferenceServer::start_seeded`] to pick one).
    pub fn start<E: Engine + Send + 'static>(
        plan: LayeredPlan,
        family: LeafFamily,
        params: EinetParams,
        max_batch: usize,
        max_wait: Duration,
    ) -> Self {
        Self::start_seeded::<E>(plan, family, params, max_batch, max_wait, 0)
    }

    /// Spawn the dispatcher with an explicit seed for the generation
    /// endpoint's RNG (reproducible serving).
    pub fn start_seeded<E: Engine + Send + 'static>(
        plan: LayeredPlan,
        family: LeafFamily,
        params: EinetParams,
        max_batch: usize,
        max_wait: Duration,
        seed: u64,
    ) -> Self {
        assert_eq!(
            params.family(),
            family,
            "parameter arena family does not match the configured family"
        );
        let backend =
            Backend::Single(Box::new(E::build(plan.clone(), family, max_batch)), params);
        Self::start_backend(plan, family, backend, max_batch, max_wait, seed)
    }

    /// Spawn the dispatcher on a backend picked from the runtime engine
    /// registry by name — the serving half of per-request backend
    /// selection (one server process per engine name; clients pick the
    /// endpoint).
    #[allow(clippy::too_many_arguments)]
    pub fn start_named(
        registry: &EngineRegistry,
        name: &str,
        plan: LayeredPlan,
        family: LeafFamily,
        params: EinetParams,
        max_batch: usize,
        max_wait: Duration,
        seed: u64,
    ) -> Result<Self> {
        assert_eq!(
            params.family(),
            family,
            "parameter arena family does not match the configured family"
        );
        let backend =
            Backend::Single(registry.build(name, plan.clone(), family, max_batch)?, params);
        Ok(Self::start_backend(
            plan, family, backend, max_batch, max_wait, seed,
        ))
    }

    /// Spawn the dispatcher over a scope-partitioned [`ShardedPool`]:
    /// every query type — including max-product MPE — executes across
    /// `n_shards` segment workers, with each worker holding only its
    /// parameter shard.
    #[allow(clippy::too_many_arguments)]
    pub fn start_sharded(
        factory: EngineFactory,
        plan: LayeredPlan,
        family: LeafFamily,
        params: EinetParams,
        n_shards: usize,
        max_batch: usize,
        max_wait: Duration,
        seed: u64,
    ) -> Self {
        let pool =
            ShardedPool::new(factory, &plan, family, &params, n_shards, max_batch);
        drop(params); // the pool's master arena is the single resident copy
        Self::start_backend(
            plan,
            family,
            Backend::Sharded(pool),
            max_batch,
            max_wait,
            seed,
        )
    }

    fn start_backend(
        plan: LayeredPlan,
        family: LeafFamily,
        backend: Backend,
        max_batch: usize,
        max_wait: Duration,
        seed: u64,
    ) -> Self {
        let (tx, rx) = mpsc::channel::<QueryRequest>();
        let handle = std::thread::spawn(move || {
            dispatcher(plan, family, backend, rx, max_batch, max_wait, seed)
        });
        Self {
            tx,
            handle: Some(handle),
        }
    }

    /// Submit any typed [`Query`]; the receiver yields the full
    /// [`QueryAnswer`] (score + completed row where applicable).
    ///
    /// Malformed requests — wrong-length evidence, an invalid mask
    /// (length, non-finite values, conditional overlap), observed
    /// evidence outside the leaf family's support (see
    /// [`LeafFamily::valid_obs`]), or a [`Query::Sample`] (whose n-row
    /// answer does not fit the one-row-per-request protocol; submit
    /// `Inpaint` rows with an all-zero mask instead) — are dropped by the
    /// dispatcher: the receiver disconnects instead of yielding a value.
    /// Evidence at marginalized dims is never read, so non-finite
    /// placeholders there are accepted.
    pub fn submit_query(&self, x: Vec<f32>, query: Query) -> Receiver<QueryAnswer> {
        let (reply, rx) = mpsc::channel();
        let _ = self.tx.send(QueryRequest {
            x,
            query,
            reply: ReplyTo::Full(reply),
        });
        rx
    }

    /// Blocking convenience for [`InferenceServer::submit_query`]. Panics
    /// if the request is rejected as malformed or the server is down.
    pub fn run_query(&self, x: Vec<f32>, query: Query) -> QueryAnswer {
        self.submit_query(x, query)
            .recv()
            .expect("request rejected or server down")
    }

    /// Legacy shim for [`Query::Marginal`]: submit evidence + mask,
    /// receive the marginal log-likelihood. Prefer
    /// [`InferenceServer::submit_query`].
    pub fn submit(&self, x: Vec<f32>, mask: Vec<f32>) -> Receiver<f32> {
        let (reply, rx) = mpsc::channel();
        let _ = self.tx.send(QueryRequest {
            x,
            query: Query::Marginal { mask },
            reply: ReplyTo::Score(reply),
        });
        rx
    }

    /// Blocking convenience call. Panics if the request is rejected as
    /// malformed (see [`InferenceServer::submit_query`]) or the server is
    /// down; use [`InferenceServer::submit`] to observe the disconnect
    /// instead.
    pub fn query(&self, x: Vec<f32>, mask: Vec<f32>) -> f32 {
        self.submit(x, mask)
            .recv()
            .expect("request rejected or server down")
    }

    /// Legacy shim for [`Query::Inpaint`]: submit a conditional-generation
    /// request; returns the receiver for the completed row. Malformed
    /// requests are dropped as in [`InferenceServer::submit_query`].
    /// Prefer [`InferenceServer::submit_query`].
    pub fn submit_generate(
        &self,
        x: Vec<f32>,
        mask: Vec<f32>,
        mode: DecodeMode,
    ) -> Receiver<Vec<f32>> {
        let (reply, rx) = mpsc::channel();
        let _ = self.tx.send(QueryRequest {
            x,
            query: Query::Inpaint { mask, mode },
            reply: ReplyTo::Row(reply),
        });
        rx
    }

    /// Blocking convenience call for conditional generation. Panics if
    /// the request is rejected as malformed or the server is down; use
    /// [`InferenceServer::submit_generate`] to observe the disconnect
    /// instead.
    pub fn generate(&self, x: Vec<f32>, mask: Vec<f32>, mode: DecodeMode) -> Vec<f32> {
        self.submit_generate(x, mask, mode)
            .recv()
            .expect("request rejected or server down")
    }

    /// Convenience for [`Query::Mpe`]: the answer's `row` is the exact
    /// max-product completion of the unobserved variables, its `score`
    /// the MPE log-score.
    pub fn submit_mpe(&self, x: Vec<f32>, mask: Vec<f32>) -> Receiver<QueryAnswer> {
        self.submit_query(x, Query::Mpe { mask })
    }

    /// Blocking convenience for [`InferenceServer::submit_mpe`].
    pub fn mpe(&self, x: Vec<f32>, mask: Vec<f32>) -> QueryAnswer {
        self.submit_mpe(x, mask)
            .recv()
            .expect("request rejected or server down")
    }

    /// Shut down and return stats. A dispatcher panic (an engine assert
    /// slipping past request validation) is propagated here rather than
    /// silently mapped to zeroed stats.
    pub fn stop(mut self) -> ServerStats {
        drop(self.tx);
        self.handle
            .take()
            .map(|h| h.join().unwrap_or_else(|e| std::panic::resume_unwind(e)))
            .unwrap_or_default()
    }
}

/// Compile one request into its plan and validate the evidence against
/// it: `None` means reject (the request never reaches the engine, where
/// it would panic — length asserts, Categorical theta indexing,
/// Binomial's `ln_choose` contract, or in debug builds the sampler's
/// categorical draw over NaN posterior weights — or poison a batch with
/// NaN). [`Query::compile`] already rejects NaN-bearing and wrong-length
/// masks, so the NaN-livelock of the old `Vec<f32> PartialEq` grouping
/// cannot recur: grouping happens on *compiled* plans, whose masks are
/// canonical and finite by construction. Evidence at marginalized dims
/// (mask 0) is never read, so NaN placeholders there — the natural
/// missing-value encoding for inpainting — stay legal.
fn compile_request(
    r: &QueryRequest,
    d: usize,
    od: usize,
    row: usize,
    family: LeafFamily,
) -> Option<QueryPlan> {
    let qp = r.query.compile(d).ok()?;
    if qp.sample_n.is_some() || r.x.len() != row {
        return None;
    }
    for pass in &qp.passes {
        for v in 0..d {
            if pass.mask[v] != 0.0 && !family.valid_obs(&r.x[v * od..(v + 1) * od]) {
                return None;
            }
        }
    }
    Some(qp)
}

#[allow(clippy::too_many_arguments)]
fn dispatcher(
    plan: LayeredPlan,
    family: LeafFamily,
    mut engine: Backend,
    rx: Receiver<QueryRequest>,
    max_batch: usize,
    max_wait: Duration,
    seed: u64,
) -> ServerStats {
    let d = plan.graph.num_vars;
    let od = family.obs_dim();
    let row = d * od;
    let mut rng = Rng::new(seed);
    let mut stats = ServerStats::default();
    let mut pending: Vec<QueryRequest> = Vec::new();
    let mut out = QueryOutput::default();
    let mut den: Vec<f32> = Vec::new();
    loop {
        // block for the first request (or shutdown)
        if pending.is_empty() {
            match rx.recv() {
                Ok(q) => pending.push(q),
                Err(_) => break,
            }
        }
        // coalesce more requests up to max_batch / max_wait
        let deadline = std::time::Instant::now() + max_wait;
        while pending.len() < max_batch {
            let now = std::time::Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(q) => pending.push(q),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        // compile once per request; invalid requests are dropped here
        // (the reply channel disconnects, the client sees an error, the
        // dispatcher keeps serving)
        let mut jobs: Vec<(QueryPlan, QueryRequest)> = Vec::with_capacity(pending.len());
        for r in pending.drain(..) {
            match compile_request(&r, d, od, row, family) {
                Some(qp) => jobs.push((qp, r)),
                None => stats.rejected += 1,
            }
        }
        // group identically-compiled plans: each group is served by one
        // set of semiring passes + one batched decode
        jobs.sort_by(|a, b| a.0.group_cmp(&b.0));
        while !jobs.is_empty() {
            let take = jobs
                .iter()
                .take_while(|j| j.0.group_cmp(&jobs[0].0).is_eq())
                .count()
                .min(max_batch);
            let group: Vec<(QueryPlan, QueryRequest)> = jobs.drain(..take).collect();
            let bn = group.len();
            let qp = &group[0].0;
            let mut xbuf = vec![0.0f32; bn * row];
            for (i, (_, q)) in group.iter().enumerate() {
                xbuf[i * row..(i + 1) * row].copy_from_slice(&q.x);
            }
            // one Arc per group: the sharded backend ships this pointer
            // to its workers with no further copies
            let x = Arc::new(xbuf);
            engine.run_plan(qp, &x, bn, &mut rng, &mut den, &mut out);
            let decoded = qp.decode.is_some();
            for (i, (_, q)) in group.iter().enumerate() {
                let score = out.scores[i];
                match &q.reply {
                    ReplyTo::Score(tx) => {
                        let _ = tx.send(score);
                    }
                    ReplyTo::Row(tx) => {
                        let _ = tx.send(out.rows[i * row..(i + 1) * row].to_vec());
                    }
                    ReplyTo::Full(tx) => {
                        let row_out = if decoded {
                            out.rows[i * row..(i + 1) * row].to_vec()
                        } else {
                            Vec::new()
                        };
                        let _ = tx.send(QueryAnswer {
                            score,
                            row: row_out,
                        });
                    }
                }
            }
            if decoded {
                stats.generated += bn;
            } else {
                stats.queries += bn;
            }
            stats.batches += 1;
            stats.max_group = stats.max_group.max(bn);
        }
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::dense::DenseEngine;
    use crate::engine::sparse::SparseEngine;
    use crate::structure::random_binary_trees;

    #[test]
    fn serves_batched_queries_correctly() {
        let nv = 6;
        let plan = LayeredPlan::compile(random_binary_trees(nv, 2, 2, 0), 3);
        let params = EinetParams::init(&plan, LeafFamily::Bernoulli, 0);
        // reference values from a direct engine
        let mut engine = DenseEngine::new(plan.clone(), LeafFamily::Bernoulli, 1);
        let mask = vec![1.0f32; nv];
        let mut want = Vec::new();
        for i in 0..20 {
            let x: Vec<f32> = (0..nv).map(|d| ((i >> d) & 1) as f32).collect();
            let mut lp = vec![0.0f32];
            engine.forward(&params, &x, &mask, &mut lp);
            want.push(lp[0]);
        }
        let server = InferenceServer::start::<DenseEngine>(
            plan,
            LeafFamily::Bernoulli,
            params,
            8,
            Duration::from_millis(5),
        );
        let receivers: Vec<_> = (0..20)
            .map(|i| {
                let x: Vec<f32> = (0..nv).map(|d| ((i >> d) & 1) as f32).collect();
                server.submit(x, mask.clone())
            })
            .collect();
        for (i, rx) in receivers.into_iter().enumerate() {
            let got = rx.recv().unwrap();
            assert!(
                (got - want[i]).abs() < 1e-5,
                "query {i}: {got} vs {}",
                want[i]
            );
        }
        let stats = server.stop();
        assert_eq!(stats.queries, 20);
        // all 20 share one mask and are submitted before any recv: at
        // least one wave must have served several at once. max_group is
        // robust to scheduler stalls where a wave-count bound is not
        // (every wave waits max_wait for more requests, so the client's
        // burst cannot be outrun 20 times in a row).
        assert!(stats.max_group >= 2, "batching never coalesced");
    }

    #[test]
    fn mixed_masks_are_grouped() {
        let nv = 4;
        let plan = LayeredPlan::compile(random_binary_trees(nv, 2, 1, 1), 2);
        let params = EinetParams::init(&plan, LeafFamily::Bernoulli, 1);
        let server = InferenceServer::start::<DenseEngine>(
            plan,
            LeafFamily::Bernoulli,
            params,
            16,
            Duration::from_millis(5),
        );
        let full = vec![1.0f32; nv];
        let mut marg = vec![1.0f32; nv];
        marg[0] = 0.0;
        let x = vec![1.0f32, 0.0, 1.0, 0.0];
        let a = server.query(x.clone(), full);
        let b = server.query(x, marg);
        // marginal likelihood >= joint likelihood (sums over x0)
        assert!(b >= a - 1e-6);
        server.stop();
    }

    #[test]
    fn malformed_requests_are_rejected_without_killing_the_dispatcher() {
        // regression: grouping once used Vec<f32> PartialEq, under which a
        // NaN-bearing mask is unequal to itself — the group drained zero
        // requests and the dispatch loop spun forever. Requests now
        // compile into canonical QueryPlans before grouping: NaN masks,
        // wrong-length evidence or masks, and NaN evidence at an observed
        // dim are dropped at the dispatch boundary — the client's reply
        // channel disconnects, the dispatcher keeps serving well-formed
        // requests, and stop() returns with the drops accounted in
        // `rejected`.
        let nv = 4;
        let plan = LayeredPlan::compile(random_binary_trees(nv, 2, 1, 2), 2);
        let params = EinetParams::init(&plan, LeafFamily::Bernoulli, 2);
        let server = InferenceServer::start::<DenseEngine>(
            plan,
            LeafFamily::Bernoulli,
            params,
            8,
            Duration::from_millis(2),
        );
        let mut nan_mask = vec![1.0f32; nv];
        nan_mask[1] = f32::NAN;
        let x = vec![1.0f32, 0.0, 1.0, 0.0];
        let nan_rx = server.submit(x.clone(), nan_mask.clone());
        let short_x_rx = server.submit(vec![0.0f32; nv - 1], vec![1.0f32; nv]);
        let short_mask_rx = server.submit(x.clone(), vec![1.0f32; nv - 1]);
        // Sample mode would draw from NaN posterior weights if either of
        // these reached the engine (debug builds panic in categorical_f32)
        let gen_rx = server.submit_generate(x.clone(), nan_mask, DecodeMode::Sample);
        let mut nan_x = x.clone();
        nan_x[2] = f32::NAN;
        let nan_x_rx = server.submit_generate(nan_x, vec![1.0f32; nv], DecodeMode::Sample);
        // NaN evidence at a marginalized dim is the missing-value
        // encoding — never read by the engine, so it must be accepted
        let mut marg_mask = vec![1.0f32; nv];
        marg_mask[3] = 0.0;
        let mut miss_x = x.clone();
        miss_x[3] = f32::NAN;
        let miss_rx = server.submit(miss_x, marg_mask);
        let good_rx = server.submit(x.clone(), vec![1.0f32; nv]);
        assert!(nan_rx.recv().is_err(), "NaN-mask query must be rejected");
        assert!(short_x_rx.recv().is_err(), "short evidence must be rejected");
        assert!(short_mask_rx.recv().is_err(), "short mask must be rejected");
        assert!(gen_rx.recv().is_err(), "NaN-mask generate must be rejected");
        assert!(nan_x_rx.recv().is_err(), "NaN-evidence generate must be rejected");
        let miss_lp = miss_rx
            .recv()
            .expect("NaN at a marginalized dim must be accepted");
        assert!(miss_lp.is_finite(), "marginal query poisoned by NaN placeholder");
        let lp = good_rx.recv().expect("dispatcher died on malformed input");
        assert!(lp.is_finite(), "well-formed query poisoned by rejects");
        let stats = server.stop();
        assert_eq!(stats.queries, 2);
        assert_eq!(stats.generated, 0);
        assert_eq!(stats.rejected, 5);
    }

    #[test]
    fn out_of_domain_categorical_evidence_is_rejected() {
        // finite but out-of-support evidence would index theta out of
        // bounds inside the leaf kernel — it must be caught at the
        // dispatch boundary like the NaN cases
        let nv = 4;
        let plan = LayeredPlan::compile(random_binary_trees(nv, 2, 1, 3), 2);
        let params = EinetParams::init(&plan, LeafFamily::Categorical { cats: 3 }, 3);
        let server = InferenceServer::start::<DenseEngine>(
            plan,
            LeafFamily::Categorical { cats: 3 },
            params,
            8,
            Duration::from_millis(2),
        );
        let mask = vec![1.0f32; nv];
        let mut bad_x = vec![1.0f32; nv];
        bad_x[0] = 10.0;
        let bad_rx = server.submit(bad_x, mask.clone());
        let good_rx = server.submit(vec![2.0f32; nv], mask);
        assert!(bad_rx.recv().is_err(), "out-of-domain evidence must be rejected");
        assert!(good_rx.recv().unwrap().is_finite());
        let stats = server.stop();
        assert_eq!(stats.queries, 1);
        assert_eq!(stats.rejected, 1);
    }

    #[test]
    fn generation_endpoint_respects_evidence_and_batches() {
        let nv = 6;
        let plan = LayeredPlan::compile(random_binary_trees(nv, 2, 2, 5), 3);
        let params = EinetParams::init(&plan, LeafFamily::Bernoulli, 5);
        let server = InferenceServer::start_seeded::<DenseEngine>(
            plan,
            LeafFamily::Bernoulli,
            params,
            8,
            Duration::from_millis(5),
            9,
        );
        let mask = vec![1.0f32, 1.0, 0.0, 0.0, 0.0, 0.0];
        let receivers: Vec<_> = (0..12)
            .map(|i| {
                let mut x = vec![0.0f32; nv];
                x[0] = (i % 2) as f32;
                x[1] = 1.0;
                (
                    x.clone(),
                    server.submit_generate(x, mask.clone(), DecodeMode::Sample),
                )
            })
            .collect();
        for (x, rx) in receivers {
            let out = rx.recv().unwrap();
            assert_eq!(out.len(), nv);
            assert_eq!(out[0], x[0], "observed dim resampled");
            assert_eq!(out[1], 1.0, "observed dim resampled");
            for &v in &out {
                assert!(v == 0.0 || v == 1.0, "non-binary completion {v}");
            }
        }
        let stats = server.stop();
        assert_eq!(stats.generated, 12);
        // one compiled plan submitted up front: at least one decode pass
        // must have served several requests at once (see the max_group
        // note in serves_batched_queries_correctly)
        assert!(stats.max_group >= 2, "generation never coalesced");
    }

    #[test]
    fn typed_queries_serve_mpe_and_conditionals() {
        // the unified endpoint: Conditional and Mpe requests batch and
        // answer identically to a direct engine running the same compiled
        // plan
        let nv = 8;
        let plan = LayeredPlan::compile(random_binary_trees(nv, 2, 2, 7), 3);
        let params = EinetParams::init(&plan, LeafFamily::Bernoulli, 7);
        let mut direct = DenseEngine::new(plan.clone(), LeafFamily::Bernoulli, 4);
        let server = InferenceServer::start_seeded::<DenseEngine>(
            plan,
            LeafFamily::Bernoulli,
            params.clone(),
            8,
            Duration::from_millis(3),
            17,
        );
        let mut emask = vec![0.0f32; nv];
        emask[0] = 1.0;
        emask[1] = 1.0;
        let mut qmask = vec![0.0f32; nv];
        qmask[2] = 1.0;
        // conditional: p(x2 | x0, x1)
        let mut x = vec![0.0f32; nv];
        x[0] = 1.0;
        x[2] = 1.0;
        let cond = server.run_query(
            x.clone(),
            Query::Conditional {
                query_mask: qmask.clone(),
                evidence_mask: emask.clone(),
            },
        );
        assert!(cond.row.is_empty(), "score-only query returned a row");
        let qp = Query::Conditional {
            query_mask: qmask,
            evidence_mask: emask.clone(),
        }
        .compile(nv)
        .unwrap();
        let mut want = QueryOutput::default();
        let mut rng = Rng::new(0);
        direct.execute(&params, &qp, &x, 1, &mut rng, &mut want);
        assert_eq!(cond.score.to_bits(), want.scores[0].to_bits());
        // MPE: completion + max-product score, bit-equal to the direct
        // engine (decode draws nothing in Mpe mode)
        let ans = server.mpe(x.clone(), emask.clone());
        let qp = Query::Mpe { mask: emask }.compile(nv).unwrap();
        let mut want = QueryOutput::default();
        direct.execute(&params, &qp, &x, 1, &mut rng, &mut want);
        assert_eq!(ans.score.to_bits(), want.scores[0].to_bits());
        assert_eq!(ans.row, want.rows);
        assert_eq!(ans.row[0], 1.0, "MPE resampled the evidence");
        // Sample{n} does not fit one-row-per-request serving: rejected
        let rej = server.submit_query(vec![0.0; nv], Query::Sample { n: 4 });
        assert!(rej.recv().is_err(), "Sample query must be rejected");
        let stats = server.stop();
        assert_eq!(stats.queries, 1);
        assert_eq!(stats.generated, 1);
        assert_eq!(stats.rejected, 1);
    }

    #[test]
    fn sharded_server_matches_direct_engine_and_generates() {
        // the segmented serving path answers log-prob queries bit-exactly
        // like a private engine, and generation (forward + sharded
        // decode) respects evidence
        let nv = 10;
        let plan = LayeredPlan::compile(random_binary_trees(nv, 2, 3, 11), 3);
        let params = EinetParams::init(&plan, LeafFamily::Bernoulli, 11);
        let mut direct = DenseEngine::new(plan.clone(), LeafFamily::Bernoulli, 1);
        let server = InferenceServer::start_sharded(
            crate::engine::registry::boxed_build::<DenseEngine>,
            plan,
            LeafFamily::Bernoulli,
            params.clone(),
            3,
            8,
            Duration::from_millis(2),
            13,
        );
        let mask = vec![1.0f32; nv];
        for i in 0..8 {
            let x: Vec<f32> = (0..nv).map(|d| ((i >> d) & 1) as f32).collect();
            let got = server.query(x.clone(), mask.clone());
            let mut want = vec![0.0f32];
            direct.forward(&params, &x, &mask, &mut want);
            assert_eq!(
                got.to_bits(),
                want[0].to_bits(),
                "sharded serving diverged: {got} vs {}",
                want[0]
            );
        }
        let mut gen_mask = vec![0.0f32; nv];
        gen_mask[0] = 1.0;
        gen_mask[1] = 1.0;
        for _ in 0..6 {
            let mut x = vec![0.0f32; nv];
            x[0] = 1.0;
            let out = server.generate(x, gen_mask.clone(), DecodeMode::Sample);
            assert_eq!(out[0], 1.0, "evidence resampled by sharded decode");
            assert_eq!(out[1], 0.0, "evidence resampled by sharded decode");
            for &v in &out {
                assert!(v == 0.0 || v == 1.0, "non-binary completion {v}");
            }
        }
        // MPE rides the same sharded backend: max-product forward across
        // the cut + sel-table backtrack, bit-equal to a direct engine
        let x = vec![1.0f32, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0];
        let ans = server.mpe(x.clone(), gen_mask.clone());
        let qp = Query::Mpe { mask: gen_mask }.compile(nv).unwrap();
        let mut want = QueryOutput::default();
        let mut rng = Rng::new(0);
        let mut direct_cap =
            DenseEngine::new(direct.plan().clone(), LeafFamily::Bernoulli, 8);
        direct_cap.execute(&params, &qp, &x, 1, &mut rng, &mut want);
        assert_eq!(
            ans.score.to_bits(),
            want.scores[0].to_bits(),
            "sharded MPE score diverged"
        );
        assert_eq!(ans.row, want.rows, "sharded MPE completion diverged");
        let stats = server.stop();
        assert_eq!(stats.queries, 8);
        assert_eq!(stats.generated, 7);
    }

    #[test]
    fn registry_named_serving_selects_backends() {
        let nv = 5;
        let plan = LayeredPlan::compile(random_binary_trees(nv, 2, 2, 4), 2);
        let params = EinetParams::init(&plan, LeafFamily::Bernoulli, 4);
        let reg = crate::engine::registry::EngineRegistry::builtin();
        assert!(InferenceServer::start_named(
            &reg,
            "no-such-backend",
            plan.clone(),
            LeafFamily::Bernoulli,
            params.clone(),
            4,
            Duration::from_millis(1),
            0,
        )
        .is_err());
        let mut answers = Vec::new();
        for name in ["dense", "sparse"] {
            let server = InferenceServer::start_named(
                &reg,
                name,
                plan.clone(),
                LeafFamily::Bernoulli,
                params.clone(),
                4,
                Duration::from_millis(1),
                0,
            )
            .unwrap();
            let x = vec![1.0f32, 0.0, 1.0, 0.0, 1.0];
            answers.push(server.query(x, vec![1.0f32; nv]));
            server.stop();
        }
        assert!(
            (answers[0] - answers[1]).abs() < 1e-4,
            "named backends disagree: {answers:?}"
        );
    }

    #[test]
    fn serves_through_any_engine_backend() {
        // the same router over the sparse baseline produces the same
        // answers — the serving path is engine-agnostic
        let nv = 5;
        let plan = LayeredPlan::compile(random_binary_trees(nv, 2, 2, 3), 3);
        let params = EinetParams::init(&plan, LeafFamily::Bernoulli, 3);
        let mut direct = DenseEngine::new(plan.clone(), LeafFamily::Bernoulli, 1);
        let mask = vec![1.0f32; nv];
        let server = InferenceServer::start::<SparseEngine>(
            plan,
            LeafFamily::Bernoulli,
            params.clone(),
            8,
            Duration::from_millis(2),
        );
        for i in 0..10 {
            let x: Vec<f32> = (0..nv).map(|d| ((i >> d) & 1) as f32).collect();
            let got = server.query(x.clone(), mask.clone());
            let mut want = vec![0.0f32];
            direct.forward(&params, &x, &mask, &mut want);
            assert!((got - want[0]).abs() < 1e-4, "{got} vs {}", want[0]);
        }
        server.stop();
    }
}
