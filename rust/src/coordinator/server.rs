//! Batched inference service: the router/batcher pattern (vLLM-style)
//! over the unified [`Query`] API.
//!
//! Clients submit typed [`Query`] values (evidence row + query) through
//! [`InferenceServer::submit_query`] — or through the legacy shims
//! ([`InferenceServer::submit`] = `Marginal`,
//! [`InferenceServer::submit_generate`] = `Inpaint`,
//! [`InferenceServer::submit_mpe`] = `Mpe`). A dispatcher thread
//! coalesces up to `max_batch` pending requests (or whatever has arrived
//! within `max_wait`), compiles each into a [`QueryPlan`] once, groups
//! requests whose compiled plans are identical
//! ([`QueryPlan::group_cmp`]), and serves each group with the plan's
//! semiring-parameterized forward passes plus (for decoding queries) ONE
//! batched top-down decode. Because grouping is by *compiled plan*, a
//! marginal, a conditional, a max-product MPE, and an inpainting request
//! each land in their own batch automatically — no parallel bespoke
//! request types.
//!
//! The dispatcher is backend-agnostic: a private engine of any type
//! implementing [`Engine`] ([`InferenceServer::start`]), a backend picked
//! by name from the runtime registry ([`InferenceServer::start_named`]),
//! a scope-partitioned [`ShardedPool`]
//! ([`InferenceServer::start_sharded`]), or a pool of remote
//! `einet shard-worker` processes reached over TCP
//! ([`InferenceServer::start_remote`]) — each worker holding only its
//! parameter shard. MPE serves sharded for free: the max-product forward
//! crosses the cut through the same boundary activation rows as
//! sum-product, and the backtrack through the same
//! one-`sel`-u32-per-region·sample tables as sampling. Batches are
//! handed to the sharded backend as a shared `Arc` (no per-call copy).
//!
//! The front door is non-blocking and bounded: submissions beyond
//! [`ServerConfig::max_pending`] are turned away immediately with
//! [`QueryError::Overloaded`] (the dispatcher never sees them), requests
//! that sit queued past [`ServerConfig::deadline`] are answered
//! [`QueryError::Expired`] instead of served stale, and every rejection
//! — malformed, out-of-domain, unsupported, overloaded, expired, or
//! backend-lost — is a typed [`QueryAnswer::Err`] on the unified
//! endpoint (the legacy scalar/row shims keep their
//! drop-the-channel contract). A dead shard worker degrades the
//! backend: the group being served and everything after it get
//! [`QueryError::BackendLost`] replies while the dispatcher keeps
//! draining, so no client ever hangs on a lost pool.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::transport::ShardError;
use super::ShardedPool;
use crate::engine::query::{reduce_class_scores, ClassReduce, Query, QueryOutput, QueryPlan};
use crate::engine::registry::{EngineFactory, EngineRegistry};
use crate::engine::{DecodeMode, EinetParams, Engine};
use crate::layers::LayeredPlan;
use crate::leaves::LeafFamily;
use crate::util::error::Result;
use crate::util::rng::Rng;

/// What the dispatcher executes batches on: one private engine, or a
/// scope-partitioned worker pool ([`ShardedPool`]) for models larger than
/// one core's cache. Both present the same two calls the router needs.
enum Backend {
    /// a private engine plus the one resident parameter arena
    Single(Box<dyn Engine + Send>, EinetParams),
    /// the pool owns the master arena (workers hold only their shards),
    /// so no second full copy lives on the serving host
    Sharded(ShardedPool),
}

impl Backend {
    /// Serve one plan-homogeneous group. The single-engine case IS
    /// [`Engine::execute`] — one source of truth for how a compiled plan
    /// runs; the sharded case replays the same plan semantics over the
    /// pool's segmented primitives (which have no boxed-engine `execute`),
    /// shipping the batch `Arc` to the workers with no per-call copy.
    fn run_plan(
        &mut self,
        qp: &QueryPlan,
        x: &Arc<Vec<f32>>,
        bn: usize,
        rng: &mut Rng,
        den: &mut Vec<f32>,
        out: &mut QueryOutput,
    ) -> std::result::Result<(), ShardError> {
        match self {
            Backend::Single(e, params) => {
                e.execute(params, qp, x.as_slice(), bn, rng, out);
                Ok(())
            }
            Backend::Sharded(p) => {
                if let Some(cr) = qp.class_reduce {
                    // class-conditional reduce: one sum-product pass, then
                    // the per-class root rows come straight off the spine
                    // and reduce exactly like Engine::execute's in-process
                    // path (shared reduce_class_scores)
                    let classes = p.num_classes();
                    out.rows.clear();
                    out.scores.clear();
                    out.scores.resize(
                        match cr {
                            ClassReduce::Argmax => bn,
                            ClassReduce::Posterior => bn * classes,
                        },
                        0.0,
                    );
                    den.clear();
                    den.resize(bn, 0.0);
                    let m0 = Arc::new(qp.passes[0].mask.clone());
                    p.forward_shared(x.clone(), 0, m0, bn, qp.passes[0].semiring, den)?;
                    let mut cls = vec![0.0f32; bn * classes];
                    p.read_class_scores(bn, &mut cls);
                    reduce_class_scores(&cls, bn, classes, cr, &mut out.scores);
                    return Ok(());
                }
                out.scores.clear();
                out.scores.resize(bn, 0.0);
                out.rows.clear();
                let m0 = Arc::new(qp.passes[0].mask.clone());
                if qp.is_ratio() && qp.decode.is_none() {
                    // double-buffered ratio: both passes go to the shards
                    // back to back, so shard compute for the denominator
                    // overlaps the spine reduce of the numerator (same
                    // imports, same spine steps — bit-identical to the
                    // sequential order)
                    let m1 = Arc::new(qp.passes[1].mask.clone());
                    p.begin_forward(x.clone(), 0, m0, bn, qp.passes[0].semiring)?;
                    p.begin_forward(x.clone(), 0, m1, bn, qp.passes[1].semiring)?;
                    p.finish_forward(&mut out.scores)?;
                    den.clear();
                    den.resize(bn, 0.0);
                    p.finish_forward(den)?;
                    for b in 0..bn {
                        out.scores[b] -= den[b];
                    }
                } else {
                    p.forward_shared(
                        x.clone(),
                        0,
                        m0.clone(),
                        bn,
                        qp.passes[0].semiring,
                        &mut out.scores,
                    )?;
                    if let Some(mode) = qp.decode {
                        out.rows.extend_from_slice(x.as_slice());
                        p.decode(bn, m0.as_slice(), mode, rng, &mut out.rows)?;
                    }
                    if qp.is_ratio() {
                        den.clear();
                        den.resize(bn, 0.0);
                        let m1 = Arc::new(qp.passes[1].mask.clone());
                        p.forward_shared(x.clone(), 0, m1, bn, qp.passes[1].semiring, den)?;
                        for b in 0..bn {
                            out.scores[b] -= den[b];
                        }
                    }
                }
                Ok(())
            }
        }
    }
}

/// Why a request was turned away instead of served. Every rejection on
/// the unified endpoint carries one of these; [`ServerStats`] tallies
/// them per cause.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum QueryError {
    /// wrong-length evidence, a mask [`Query::compile`] rejects
    /// (wrong length, non-finite values, overlapping conditional masks),
    /// or a classify/posterior query against a circuit that carries no
    /// class roots (see [`crate::layers::LayeredPlan::with_classes`])
    Malformed,
    /// observed evidence outside the leaf family's support (would index
    /// theta out of bounds or poison the batch with NaN)
    OutOfDomain,
    /// a [`Query::Sample`] — its n-row answer does not fit the
    /// one-row-per-request protocol; submit `Inpaint` rows with an
    /// all-zero mask instead
    UnsupportedSample,
    /// admission control: more than [`ServerConfig::max_pending`]
    /// requests were already queued, so this one never entered
    Overloaded,
    /// the request sat queued past [`ServerConfig::deadline`]
    Expired,
    /// the serving backend lost a shard worker; the pool is degraded and
    /// cannot answer (restart workers and reconnect)
    BackendLost,
}

impl std::fmt::Display for QueryError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            QueryError::Malformed => write!(f, "malformed request"),
            QueryError::OutOfDomain => {
                write!(f, "observed evidence outside the leaf family's support")
            }
            QueryError::UnsupportedSample => {
                write!(f, "Sample queries are not servable per-request")
            }
            QueryError::Overloaded => write!(f, "server overloaded: pending queue full"),
            QueryError::Expired => write!(f, "request deadline expired before serving"),
            QueryError::BackendLost => write!(f, "serving backend lost a shard worker"),
        }
    }
}

impl std::error::Error for QueryError {}

/// A served answer: the per-row log score (marginal / conditional /
/// max-product MPE, depending on the query) plus, for decoding queries,
/// the completed `[D, obs_dim]` row (observed dims untouched). Class
/// queries bend the convention: `Classify` carries the predicted class
/// index in `score` (empty `row`), `Posterior` carries the `C` log-
/// posteriors in `row` and the winning class's log-posterior in `score`.
#[derive(Clone, Debug)]
pub struct QueryOk {
    pub score: f32,
    /// empty for score-only queries
    pub row: Vec<f32>,
}

/// What the unified endpoint delivers: the answer, or a typed rejection.
/// (The legacy scalar/row shims signal rejection by dropping the reply
/// channel instead — they have no payload to carry the cause.)
#[derive(Clone, Debug)]
pub enum QueryAnswer {
    Ok(QueryOk),
    Err(QueryError),
}

impl QueryAnswer {
    /// The answer, or `None` if the request was rejected.
    pub fn ok(self) -> Option<QueryOk> {
        match self {
            QueryAnswer::Ok(a) => Some(a),
            QueryAnswer::Err(_) => None,
        }
    }

    pub fn into_result(self) -> std::result::Result<QueryOk, QueryError> {
        match self {
            QueryAnswer::Ok(a) => Ok(a),
            QueryAnswer::Err(e) => Err(e),
        }
    }
}

/// How a request wants its answer delivered: the legacy endpoints keep
/// their scalar/row channel types, the unified endpoint gets everything.
enum ReplyTo {
    Score(Sender<f32>),
    Row(Sender<Vec<f32>>),
    Full(Sender<QueryAnswer>),
}

/// One in-flight request: evidence row + typed query + reply channel +
/// the submission instant its deadline is measured from.
struct QueryRequest {
    x: Vec<f32>,
    query: Query,
    reply: ReplyTo,
    enqueued: Instant,
}

/// Admission state shared between the submitting threads and the
/// dispatcher: the in-flight depth is checked (and a slot reserved)
/// BEFORE a request enters the channel, so overload rejection is
/// immediate, and the slot is held until the request leaves the system
/// (served or rejected) — so `max_pending` bounds TOTAL in-flight work:
/// channel occupancy plus everything parked in the dispatcher's
/// coalescing queue across waves, not just the channel.
struct Gate {
    depth: AtomicUsize,
    max_pending: usize,
    overloaded: AtomicUsize,
}

impl Gate {
    /// Reserve an in-flight slot; `false` means the server is already
    /// carrying `max_pending` requests and this one must be turned away.
    fn admit(&self) -> bool {
        let mut cur = self.depth.load(Ordering::Relaxed);
        loop {
            if cur >= self.max_pending {
                self.overloaded.fetch_add(1, Ordering::Relaxed);
                return false;
            }
            match self.depth.compare_exchange_weak(
                cur,
                cur + 1,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return true,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Release one slot: a request was served or rejected.
    fn release(&self) {
        self.depth.fetch_sub(1, Ordering::Relaxed);
    }

    /// Release a whole served group's slots at once.
    fn release_n(&self, n: usize) {
        self.depth.fetch_sub(n, Ordering::Relaxed);
    }
}

/// Handle to the running service.
pub struct InferenceServer {
    tx: Sender<QueryRequest>,
    gate: Arc<Gate>,
    handle: Option<JoinHandle<ServerStats>>,
}

/// Serving knobs beyond the plan itself. The legacy constructors
/// ([`InferenceServer::start`] etc.) keep their `(max_batch, max_wait)`
/// signatures and fill the rest with these defaults; the config-taking
/// constructors ([`InferenceServer::start_with`],
/// [`InferenceServer::start_remote`]) expose everything.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// largest coalesced batch (also the backend's batch capacity)
    pub max_batch: usize,
    /// how long the dispatcher holds the FIRST request of an idle wave
    /// open for co-travellers; leftovers of a burst are served
    /// immediately, never re-delayed
    pub max_wait: Duration,
    /// admission bound: at most this many requests in flight — queued
    /// ahead of the dispatcher, parked for coalescing, or being served;
    /// a slot is held from submission until the request is answered, so
    /// dispatcher memory stays bounded under sustained overload and
    /// submissions beyond the bound are rejected
    /// [`QueryError::Overloaded`] without blocking (0 turns every
    /// request away — a deterministic test hook)
    pub max_pending: usize,
    /// per-request deadline measured from submission: a request still
    /// queued when `enqueued.elapsed() >= deadline` is answered
    /// [`QueryError::Expired`] instead of served stale — checked both at
    /// dispatcher intake and again as a batch group forms, so requests
    /// parked for coalescing across waves cannot dodge it
    /// (`Duration::MAX` = never; `Duration::ZERO` expires everything —
    /// the deterministic test hook)
    pub deadline: Duration,
    /// seed for the generation endpoint's RNG (reproducible serving)
    pub seed: u64,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            max_batch: 32,
            max_wait: Duration::from_millis(2),
            max_pending: 1024,
            deadline: Duration::MAX,
            seed: 0,
        }
    }
}

/// Throughput accounting returned on shutdown.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServerStats {
    /// score-only queries served (LogLik / Marginal / Conditional)
    pub queries: usize,
    pub batches: usize,
    /// decoded rows produced (Inpaint / Mpe)
    pub generated: usize,
    /// requests turned away, total across every cause below
    pub rejected: usize,
    /// wrong-length evidence or a mask `Query::compile` rejects
    pub rej_malformed: usize,
    /// observed evidence outside the leaf family's support
    pub rej_out_of_domain: usize,
    /// `Sample` queries (unsupported per-request)
    pub rej_unsupported: usize,
    /// turned away at the admission gate (pending queue full)
    pub rej_overloaded: usize,
    /// expired in the queue past the per-request deadline
    pub rej_expired: usize,
    /// rejected because the sharded backend lost a worker
    pub rej_backend_lost: usize,
    /// largest number of requests served by a single batched pass — the
    /// coalescing witness the tests assert on (>= 2 proves batching
    /// without depending on wall-clock wave counts)
    pub max_group: usize,
}

impl ServerStats {
    fn tally(&mut self, e: &QueryError) {
        self.rejected += 1;
        match e {
            QueryError::Malformed => self.rej_malformed += 1,
            QueryError::OutOfDomain => self.rej_out_of_domain += 1,
            QueryError::UnsupportedSample => self.rej_unsupported += 1,
            QueryError::Overloaded => self.rej_overloaded += 1,
            QueryError::Expired => self.rej_expired += 1,
            QueryError::BackendLost => self.rej_backend_lost += 1,
        }
    }
}

impl InferenceServer {
    /// Spawn the dispatcher with its private engine of type `E` (sampler
    /// seeded with 0; use [`InferenceServer::start_seeded`] to pick one).
    pub fn start<E: Engine + Send + 'static>(
        plan: LayeredPlan,
        family: LeafFamily,
        params: EinetParams,
        max_batch: usize,
        max_wait: Duration,
    ) -> Self {
        Self::start_seeded::<E>(plan, family, params, max_batch, max_wait, 0)
    }

    /// Spawn the dispatcher with an explicit seed for the generation
    /// endpoint's RNG (reproducible serving).
    pub fn start_seeded<E: Engine + Send + 'static>(
        plan: LayeredPlan,
        family: LeafFamily,
        params: EinetParams,
        max_batch: usize,
        max_wait: Duration,
        seed: u64,
    ) -> Self {
        Self::start_with::<E>(
            plan,
            family,
            params,
            ServerConfig {
                max_batch,
                max_wait,
                seed,
                ..ServerConfig::default()
            },
        )
    }

    /// Spawn the dispatcher with a full [`ServerConfig`] (admission bound
    /// and per-request deadline included).
    pub fn start_with<E: Engine + Send + 'static>(
        plan: LayeredPlan,
        family: LeafFamily,
        params: EinetParams,
        cfg: ServerConfig,
    ) -> Self {
        assert_eq!(
            params.family(),
            family,
            "parameter arena family does not match the configured family"
        );
        let backend = Backend::Single(
            Box::new(E::build(plan.clone(), family, cfg.max_batch)),
            params,
        );
        Self::start_backend(plan, family, backend, cfg)
    }

    /// Spawn the dispatcher on a backend picked from the runtime engine
    /// registry by name — the serving half of per-request backend
    /// selection (one server process per engine name; clients pick the
    /// endpoint).
    #[allow(clippy::too_many_arguments)]
    pub fn start_named(
        registry: &EngineRegistry,
        name: &str,
        plan: LayeredPlan,
        family: LeafFamily,
        params: EinetParams,
        max_batch: usize,
        max_wait: Duration,
        seed: u64,
    ) -> Result<Self> {
        assert_eq!(
            params.family(),
            family,
            "parameter arena family does not match the configured family"
        );
        let backend =
            Backend::Single(registry.build(name, plan.clone(), family, max_batch)?, params);
        Ok(Self::start_backend(
            plan,
            family,
            backend,
            ServerConfig {
                max_batch,
                max_wait,
                seed,
                ..ServerConfig::default()
            },
        ))
    }

    /// Spawn the dispatcher over a scope-partitioned [`ShardedPool`]:
    /// every query type — including max-product MPE — executes across
    /// `n_shards` segment workers, with each worker holding only its
    /// parameter shard.
    #[allow(clippy::too_many_arguments)]
    pub fn start_sharded(
        factory: EngineFactory,
        plan: LayeredPlan,
        family: LeafFamily,
        params: EinetParams,
        n_shards: usize,
        max_batch: usize,
        max_wait: Duration,
        seed: u64,
    ) -> Self {
        let pool =
            ShardedPool::new(factory, &plan, family, &params, n_shards, max_batch);
        drop(params); // the pool's master arena is the single resident copy
        Self::start_backend(
            plan,
            family,
            Backend::Sharded(pool),
            ServerConfig {
                max_batch,
                max_wait,
                seed,
                ..ServerConfig::default()
            },
        )
    }

    /// Spawn the dispatcher over remote `einet shard-worker` processes:
    /// [`ShardedPool::connect`] hands each address its deterministic
    /// [`super::transport::WorkerConfig`] and streams the parameter
    /// spans, then serving proceeds exactly as in
    /// [`InferenceServer::start_sharded`] — same frames, same
    /// bit-identical answers.
    #[allow(clippy::too_many_arguments)]
    pub fn start_remote(
        addrs: &[String],
        structure: &str,
        engine_name: &str,
        plan: LayeredPlan,
        family: LeafFamily,
        params: EinetParams,
        n_shards: usize,
        cfg: ServerConfig,
    ) -> Result<Self> {
        let pool = ShardedPool::connect(
            addrs,
            structure,
            engine_name,
            &plan,
            family,
            &params,
            n_shards,
            cfg.max_batch,
        )?;
        drop(params); // the pool's master arena is the single resident copy
        Ok(Self::start_backend(
            plan,
            family,
            Backend::Sharded(pool),
            cfg,
        ))
    }

    fn start_backend(
        plan: LayeredPlan,
        family: LeafFamily,
        backend: Backend,
        cfg: ServerConfig,
    ) -> Self {
        let (tx, rx) = mpsc::channel::<QueryRequest>();
        let gate = Arc::new(Gate {
            depth: AtomicUsize::new(0),
            max_pending: cfg.max_pending,
            overloaded: AtomicUsize::new(0),
        });
        let gate_d = gate.clone();
        let handle = std::thread::spawn(move || {
            dispatcher(plan, family, backend, rx, gate_d, cfg)
        });
        Self {
            tx,
            gate,
            handle: Some(handle),
        }
    }

    /// Submit any typed [`Query`]; the receiver yields a
    /// [`QueryAnswer`]: `Ok` with score + completed row where
    /// applicable, or a typed `Err` — [`QueryError::Malformed`] /
    /// [`QueryError::OutOfDomain`] (see [`LeafFamily::valid_obs`]) /
    /// [`QueryError::UnsupportedSample`] for requests the dispatcher
    /// turns away, [`QueryError::Overloaded`] when the admission gate is
    /// full (delivered immediately, without entering the queue),
    /// [`QueryError::Expired`] for requests that out-sat their deadline,
    /// [`QueryError::BackendLost`] when the sharded backend has lost a
    /// worker. Evidence at marginalized dims is never read, so
    /// non-finite placeholders there are accepted.
    pub fn submit_query(&self, x: Vec<f32>, query: Query) -> Receiver<QueryAnswer> {
        let (reply, rx) = mpsc::channel();
        if !self.gate.admit() {
            let _ = reply.send(QueryAnswer::Err(QueryError::Overloaded));
            return rx;
        }
        let _ = self.tx.send(QueryRequest {
            x,
            query,
            reply: ReplyTo::Full(reply),
            enqueued: Instant::now(),
        });
        rx
    }

    /// Blocking convenience for [`InferenceServer::submit_query`]. Panics
    /// if the request is rejected or the server is down.
    pub fn run_query(&self, x: Vec<f32>, query: Query) -> QueryOk {
        match self.submit_query(x, query).recv() {
            Ok(QueryAnswer::Ok(ans)) => ans,
            Ok(QueryAnswer::Err(e)) => panic!("request rejected: {e}"),
            Err(_) => panic!("server down"),
        }
    }

    /// Legacy shim for [`Query::Marginal`]: submit evidence + mask,
    /// receive the marginal log-likelihood. Rejections of any cause
    /// (including overload) drop the reply channel: the receiver
    /// disconnects instead of yielding a value. Prefer
    /// [`InferenceServer::submit_query`] for typed rejections.
    pub fn submit(&self, x: Vec<f32>, mask: Vec<f32>) -> Receiver<f32> {
        let (reply, rx) = mpsc::channel();
        if !self.gate.admit() {
            return rx;
        }
        let _ = self.tx.send(QueryRequest {
            x,
            query: Query::Marginal { mask },
            reply: ReplyTo::Score(reply),
            enqueued: Instant::now(),
        });
        rx
    }

    /// Blocking convenience call. Panics if the request is rejected as
    /// malformed (see [`InferenceServer::submit_query`]) or the server is
    /// down; use [`InferenceServer::submit`] to observe the disconnect
    /// instead.
    pub fn query(&self, x: Vec<f32>, mask: Vec<f32>) -> f32 {
        self.submit(x, mask)
            .recv()
            .expect("request rejected or server down")
    }

    /// Legacy shim for [`Query::Inpaint`]: submit a conditional-generation
    /// request; returns the receiver for the completed row. Malformed
    /// requests are dropped as in [`InferenceServer::submit_query`].
    /// Prefer [`InferenceServer::submit_query`].
    pub fn submit_generate(
        &self,
        x: Vec<f32>,
        mask: Vec<f32>,
        mode: DecodeMode,
    ) -> Receiver<Vec<f32>> {
        let (reply, rx) = mpsc::channel();
        if !self.gate.admit() {
            return rx;
        }
        let _ = self.tx.send(QueryRequest {
            x,
            query: Query::Inpaint { mask, mode },
            reply: ReplyTo::Row(reply),
            enqueued: Instant::now(),
        });
        rx
    }

    /// Blocking convenience call for conditional generation. Panics if
    /// the request is rejected as malformed or the server is down; use
    /// [`InferenceServer::submit_generate`] to observe the disconnect
    /// instead.
    pub fn generate(&self, x: Vec<f32>, mask: Vec<f32>, mode: DecodeMode) -> Vec<f32> {
        self.submit_generate(x, mask, mode)
            .recv()
            .expect("request rejected or server down")
    }

    /// Convenience for [`Query::Mpe`]: the answer's `row` is the exact
    /// max-product completion of the unobserved variables, its `score`
    /// the MPE log-score.
    pub fn submit_mpe(&self, x: Vec<f32>, mask: Vec<f32>) -> Receiver<QueryAnswer> {
        self.submit_query(x, Query::Mpe { mask })
    }

    /// Blocking convenience for [`InferenceServer::submit_mpe`]. Panics
    /// if the request is rejected or the server is down.
    pub fn mpe(&self, x: Vec<f32>, mask: Vec<f32>) -> QueryOk {
        match self.submit_mpe(x, mask).recv() {
            Ok(QueryAnswer::Ok(ans)) => ans,
            Ok(QueryAnswer::Err(e)) => panic!("request rejected: {e}"),
            Err(_) => panic!("server down"),
        }
    }

    /// Convenience for [`Query::Classify`] on a class-conditional circuit
    /// ([`crate::layers::LayeredPlan::with_classes`]): the answer's
    /// `score` carries the predicted class index as `f32`, its `row` is
    /// empty. `mask[d] == 0` marginalizes variable `d` out of the
    /// evidence. Against a circuit without class roots the request is
    /// rejected [`QueryError::Malformed`].
    pub fn submit_classify(&self, x: Vec<f32>, mask: Vec<f32>) -> Receiver<QueryAnswer> {
        self.submit_query(x, Query::Classify { mask })
    }

    /// Blocking convenience for [`InferenceServer::submit_classify`]:
    /// returns the predicted class. Panics if the request is rejected or
    /// the server is down.
    pub fn classify(&self, x: Vec<f32>, mask: Vec<f32>) -> usize {
        match self.submit_classify(x, mask).recv() {
            Ok(QueryAnswer::Ok(ans)) => ans.score as usize,
            Ok(QueryAnswer::Err(e)) => panic!("request rejected: {e}"),
            Err(_) => panic!("server down"),
        }
    }

    /// Convenience for [`Query::Posterior`]: the answer's `row` carries
    /// the `C` normalized log-posteriors `log p(c | x_e)` (uniform class
    /// prior), its `score` the winning class's log-posterior.
    pub fn submit_posterior(&self, x: Vec<f32>, mask: Vec<f32>) -> Receiver<QueryAnswer> {
        self.submit_query(x, Query::Posterior { mask })
    }

    /// Blocking convenience for [`InferenceServer::submit_posterior`]:
    /// returns the `C` log-posteriors. Panics if the request is rejected
    /// or the server is down.
    pub fn posterior(&self, x: Vec<f32>, mask: Vec<f32>) -> Vec<f32> {
        match self.submit_posterior(x, mask).recv() {
            Ok(QueryAnswer::Ok(ans)) => ans.row,
            Ok(QueryAnswer::Err(e)) => panic!("request rejected: {e}"),
            Err(_) => panic!("server down"),
        }
    }

    /// Shut down and return stats (admission-gate rejections folded in).
    /// A dispatcher panic (an engine assert slipping past request
    /// validation) is propagated here rather than silently mapped to
    /// zeroed stats.
    pub fn stop(mut self) -> ServerStats {
        drop(self.tx);
        let mut stats = self
            .handle
            .take()
            .map(|h| h.join().unwrap_or_else(|e| std::panic::resume_unwind(e)))
            .unwrap_or_default();
        let over = self.gate.overloaded.load(Ordering::Relaxed);
        stats.rej_overloaded += over;
        stats.rejected += over;
        stats
    }
}

/// Compile one request into its plan and validate the evidence against
/// it: `None` means reject (the request never reaches the engine, where
/// it would panic — length asserts, Categorical theta indexing,
/// Binomial's `ln_choose` contract, or in debug builds the sampler's
/// categorical draw over NaN posterior weights — or poison a batch with
/// NaN). [`Query::compile`] already rejects NaN-bearing and wrong-length
/// masks, so the NaN-livelock of the old `Vec<f32> PartialEq` grouping
/// cannot recur: grouping happens on *compiled* plans, whose masks are
/// canonical and finite by construction. Evidence at marginalized dims
/// (mask 0) is never read, so NaN placeholders there — the natural
/// missing-value encoding for inpainting — stay legal.
fn compile_request(
    r: &QueryRequest,
    d: usize,
    od: usize,
    row: usize,
    family: LeafFamily,
    classes: usize,
) -> std::result::Result<QueryPlan, QueryError> {
    let qp = r.query.compile(d).map_err(|_| QueryError::Malformed)?;
    if qp.sample_n.is_some() {
        return Err(QueryError::UnsupportedSample);
    }
    if qp.class_reduce.is_some() && classes < 2 {
        // a classify/posterior request against a plain generative circuit
        // would trip the engine's assert; turn it away typed instead
        return Err(QueryError::Malformed);
    }
    if r.x.len() != row {
        return Err(QueryError::Malformed);
    }
    for pass in &qp.passes {
        for v in 0..d {
            if pass.mask[v] != 0.0 && !family.valid_obs(&r.x[v * od..(v + 1) * od]) {
                return Err(QueryError::OutOfDomain);
            }
        }
    }
    Ok(qp)
}

/// Deliver a typed rejection: the unified endpoint gets the cause, the
/// legacy scalar/row shims get their drop-the-channel contract (the
/// sender is dropped here, the receiver disconnects). The request is
/// leaving the system, so its admission slot is released here.
fn reject(r: QueryRequest, e: QueryError, stats: &mut ServerStats, gate: &Gate) {
    stats.tally(&e);
    gate.release();
    if let ReplyTo::Full(tx) = r.reply {
        let _ = tx.send(QueryAnswer::Err(e));
    }
}

fn dispatcher(
    plan: LayeredPlan,
    family: LeafFamily,
    mut engine: Backend,
    rx: Receiver<QueryRequest>,
    gate: Arc<Gate>,
    cfg: ServerConfig,
) -> ServerStats {
    let d = plan.graph.num_vars;
    let od = family.obs_dim();
    let row = d * od;
    let classes = plan.num_classes();
    let mut rng = Rng::new(cfg.seed);
    let mut stats = ServerStats::default();
    let mut jobs: Vec<(QueryPlan, QueryRequest)> = Vec::new();
    let mut out = QueryOutput::default();
    let mut den: Vec<f32> = Vec::new();
    // intake: enforce the deadline, compile, reject typed — only
    // well-formed live requests reach the job queue. The admission slot
    // is NOT released here: it stays held until the request is served or
    // rejected, so `max_pending` bounds everything in flight (channel +
    // the coalescing queue) and sustained overload reports Overloaded
    // instead of growing `jobs` without bound.
    let intake = |q: QueryRequest,
                  jobs: &mut Vec<(QueryPlan, QueryRequest)>,
                  stats: &mut ServerStats| {
        if q.enqueued.elapsed() >= cfg.deadline {
            reject(q, QueryError::Expired, stats, &gate);
            return;
        }
        match compile_request(&q, d, od, row, family, classes) {
            Ok(qp) => jobs.push((qp, q)),
            Err(e) => reject(q, e, stats, &gate),
        }
    };
    let mut open = true;
    while open || !jobs.is_empty() {
        // block only when idle: a leftover from the previous wave is
        // served immediately, never re-delayed behind a fresh window
        let mut fresh = false;
        if open && jobs.is_empty() {
            match rx.recv() {
                Ok(q) => {
                    intake(q, &mut jobs, &mut stats);
                    fresh = true;
                }
                Err(_) => {
                    open = false;
                    continue;
                }
            }
        }
        // non-blocking drain of everything already queued
        while open {
            match rx.try_recv() {
                Ok(q) => intake(q, &mut jobs, &mut stats),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    open = false;
                    break;
                }
            }
        }
        // the coalescing window opens ONLY when this wave began from an
        // idle blocking wait AND the batch still has room (the old loop
        // re-opened `max_wait` on every iteration, delaying leftovers
        // that were ready to serve)
        if open && fresh && jobs.len() < cfg.max_batch {
            let window = Instant::now() + cfg.max_wait;
            while jobs.len() < cfg.max_batch {
                let now = Instant::now();
                if now >= window {
                    break;
                }
                match rx.recv_timeout(window - now) {
                    Ok(q) => intake(q, &mut jobs, &mut stats),
                    Err(RecvTimeoutError::Timeout) => break,
                    Err(RecvTimeoutError::Disconnected) => {
                        open = false;
                        break;
                    }
                }
            }
        }
        if jobs.is_empty() {
            continue;
        }
        // group identically-compiled plans and serve ONE group per
        // iteration: each group is one set of semiring passes + one
        // batched decode; leftovers stay queued and go out next round
        // without a new wait
        jobs.sort_by(|a, b| a.0.group_cmp(&b.0));
        let take = jobs
            .iter()
            .take_while(|j| j.0.group_cmp(&jobs[0].0).is_eq())
            .count()
            .min(cfg.max_batch);
        // intake checked the deadline once, but a request can out-sit it
        // parked in `jobs` across waves; re-check as the group forms so
        // nothing is ever served stale
        let (group, stale): (Vec<(QueryPlan, QueryRequest)>, Vec<_>) = jobs
            .drain(..take)
            .partition(|(_, q)| q.enqueued.elapsed() < cfg.deadline);
        for (_, q) in stale {
            reject(q, QueryError::Expired, &mut stats, &gate);
        }
        if group.is_empty() {
            continue;
        }
        let bn = group.len();
        let qp = &group[0].0;
        let decoded = qp.decode.is_some();
        let mut xbuf = vec![0.0f32; bn * row];
        for (i, (_, q)) in group.iter().enumerate() {
            xbuf[i * row..(i + 1) * row].copy_from_slice(&q.x);
        }
        // one Arc per group: the sharded backend ships this pointer
        // to its workers with no further copies
        let x = Arc::new(xbuf);
        if let Err(e) = engine.run_plan(qp, &x, bn, &mut rng, &mut den, &mut out) {
            // a lost worker degrades the pool, it does not kill serving:
            // this group — and every later request, via the pool's
            // fail-fast Unhealthy — gets a typed BackendLost reply
            crate::info!("serving backend degraded: {e}");
            for (_, q) in group {
                reject(q, QueryError::BackendLost, &mut stats, &gate);
            }
            continue;
        }
        // hand the slots back BEFORE the replies go out: a client that
        // just received its answer must be able to submit again without
        // racing the release
        gate.release_n(bn);
        // per-request score stride: 1 everywhere except Posterior, whose
        // group answer is [bn, C] log-posteriors
        let stride = out.scores.len() / bn;
        for (i, (_, q)) in group.iter().enumerate() {
            match &q.reply {
                ReplyTo::Score(tx) => {
                    let _ = tx.send(out.scores[i * stride]);
                }
                ReplyTo::Row(tx) => {
                    let _ = tx.send(out.rows[i * row..(i + 1) * row].to_vec());
                }
                ReplyTo::Full(tx) => {
                    let (score, row_out) = if stride > 1 {
                        // Posterior: the C log-posteriors travel in `row`,
                        // the score is the winning class's log-posterior
                        let post = out.scores[i * stride..(i + 1) * stride].to_vec();
                        let best =
                            post.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
                        (best, post)
                    } else if decoded {
                        (out.scores[i], out.rows[i * row..(i + 1) * row].to_vec())
                    } else {
                        (out.scores[i], Vec::new())
                    };
                    let _ = tx.send(QueryAnswer::Ok(QueryOk {
                        score,
                        row: row_out,
                    }));
                }
            }
        }
        if decoded {
            stats.generated += bn;
        } else {
            stats.queries += bn;
        }
        stats.batches += 1;
        stats.max_group = stats.max_group.max(bn);
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::dense::DenseEngine;
    use crate::engine::sparse::SparseEngine;
    use crate::structure::random_binary_trees;

    #[test]
    fn serves_batched_queries_correctly() {
        let nv = 6;
        let plan = LayeredPlan::compile(random_binary_trees(nv, 2, 2, 0), 3);
        let params = EinetParams::init(&plan, LeafFamily::Bernoulli, 0);
        // reference values from a direct engine
        let mut engine = DenseEngine::new(plan.clone(), LeafFamily::Bernoulli, 1);
        let mask = vec![1.0f32; nv];
        let mut want = Vec::new();
        for i in 0..20 {
            let x: Vec<f32> = (0..nv).map(|d| ((i >> d) & 1) as f32).collect();
            let mut lp = vec![0.0f32];
            engine.forward(&params, &x, &mask, &mut lp);
            want.push(lp[0]);
        }
        let server = InferenceServer::start::<DenseEngine>(
            plan,
            LeafFamily::Bernoulli,
            params,
            8,
            Duration::from_millis(5),
        );
        let receivers: Vec<_> = (0..20)
            .map(|i| {
                let x: Vec<f32> = (0..nv).map(|d| ((i >> d) & 1) as f32).collect();
                server.submit(x, mask.clone())
            })
            .collect();
        for (i, rx) in receivers.into_iter().enumerate() {
            let got = rx.recv().unwrap();
            assert!(
                (got - want[i]).abs() < 1e-5,
                "query {i}: {got} vs {}",
                want[i]
            );
        }
        let stats = server.stop();
        assert_eq!(stats.queries, 20);
        // all 20 share one mask and are submitted before any recv: at
        // least one wave must have served several at once. max_group is
        // robust to scheduler stalls where a wave-count bound is not
        // (every wave waits max_wait for more requests, so the client's
        // burst cannot be outrun 20 times in a row).
        assert!(stats.max_group >= 2, "batching never coalesced");
    }

    #[test]
    fn mixed_masks_are_grouped() {
        let nv = 4;
        let plan = LayeredPlan::compile(random_binary_trees(nv, 2, 1, 1), 2);
        let params = EinetParams::init(&plan, LeafFamily::Bernoulli, 1);
        let server = InferenceServer::start::<DenseEngine>(
            plan,
            LeafFamily::Bernoulli,
            params,
            16,
            Duration::from_millis(5),
        );
        let full = vec![1.0f32; nv];
        let mut marg = vec![1.0f32; nv];
        marg[0] = 0.0;
        let x = vec![1.0f32, 0.0, 1.0, 0.0];
        let a = server.query(x.clone(), full);
        let b = server.query(x, marg);
        // marginal likelihood >= joint likelihood (sums over x0)
        assert!(b >= a - 1e-6);
        server.stop();
    }

    #[test]
    fn malformed_requests_are_rejected_without_killing_the_dispatcher() {
        // regression: grouping once used Vec<f32> PartialEq, under which a
        // NaN-bearing mask is unequal to itself — the group drained zero
        // requests and the dispatch loop spun forever. Requests now
        // compile into canonical QueryPlans before grouping: NaN masks,
        // wrong-length evidence or masks, and NaN evidence at an observed
        // dim are dropped at the dispatch boundary — the client's reply
        // channel disconnects, the dispatcher keeps serving well-formed
        // requests, and stop() returns with the drops accounted in
        // `rejected`.
        let nv = 4;
        let plan = LayeredPlan::compile(random_binary_trees(nv, 2, 1, 2), 2);
        let params = EinetParams::init(&plan, LeafFamily::Bernoulli, 2);
        let server = InferenceServer::start::<DenseEngine>(
            plan,
            LeafFamily::Bernoulli,
            params,
            8,
            Duration::from_millis(2),
        );
        let mut nan_mask = vec![1.0f32; nv];
        nan_mask[1] = f32::NAN;
        let x = vec![1.0f32, 0.0, 1.0, 0.0];
        let nan_rx = server.submit(x.clone(), nan_mask.clone());
        let short_x_rx = server.submit(vec![0.0f32; nv - 1], vec![1.0f32; nv]);
        let short_mask_rx = server.submit(x.clone(), vec![1.0f32; nv - 1]);
        // Sample mode would draw from NaN posterior weights if either of
        // these reached the engine (debug builds panic in categorical_f32)
        let gen_rx = server.submit_generate(x.clone(), nan_mask, DecodeMode::Sample);
        let mut nan_x = x.clone();
        nan_x[2] = f32::NAN;
        let nan_x_rx = server.submit_generate(nan_x, vec![1.0f32; nv], DecodeMode::Sample);
        // NaN evidence at a marginalized dim is the missing-value
        // encoding — never read by the engine, so it must be accepted
        let mut marg_mask = vec![1.0f32; nv];
        marg_mask[3] = 0.0;
        let mut miss_x = x.clone();
        miss_x[3] = f32::NAN;
        let miss_rx = server.submit(miss_x, marg_mask);
        let good_rx = server.submit(x.clone(), vec![1.0f32; nv]);
        assert!(nan_rx.recv().is_err(), "NaN-mask query must be rejected");
        assert!(short_x_rx.recv().is_err(), "short evidence must be rejected");
        assert!(short_mask_rx.recv().is_err(), "short mask must be rejected");
        assert!(gen_rx.recv().is_err(), "NaN-mask generate must be rejected");
        assert!(nan_x_rx.recv().is_err(), "NaN-evidence generate must be rejected");
        let miss_lp = miss_rx
            .recv()
            .expect("NaN at a marginalized dim must be accepted");
        assert!(miss_lp.is_finite(), "marginal query poisoned by NaN placeholder");
        let lp = good_rx.recv().expect("dispatcher died on malformed input");
        assert!(lp.is_finite(), "well-formed query poisoned by rejects");
        let stats = server.stop();
        assert_eq!(stats.queries, 2);
        assert_eq!(stats.generated, 0);
        assert_eq!(stats.rejected, 5);
    }

    #[test]
    fn out_of_domain_categorical_evidence_is_rejected() {
        // finite but out-of-support evidence would index theta out of
        // bounds inside the leaf kernel — it must be caught at the
        // dispatch boundary like the NaN cases
        let nv = 4;
        let plan = LayeredPlan::compile(random_binary_trees(nv, 2, 1, 3), 2);
        let params = EinetParams::init(&plan, LeafFamily::Categorical { cats: 3 }, 3);
        let server = InferenceServer::start::<DenseEngine>(
            plan,
            LeafFamily::Categorical { cats: 3 },
            params,
            8,
            Duration::from_millis(2),
        );
        let mask = vec![1.0f32; nv];
        let mut bad_x = vec![1.0f32; nv];
        bad_x[0] = 10.0;
        let bad_rx = server.submit(bad_x, mask.clone());
        let good_rx = server.submit(vec![2.0f32; nv], mask);
        assert!(bad_rx.recv().is_err(), "out-of-domain evidence must be rejected");
        assert!(good_rx.recv().unwrap().is_finite());
        let stats = server.stop();
        assert_eq!(stats.queries, 1);
        assert_eq!(stats.rejected, 1);
    }

    #[test]
    fn generation_endpoint_respects_evidence_and_batches() {
        let nv = 6;
        let plan = LayeredPlan::compile(random_binary_trees(nv, 2, 2, 5), 3);
        let params = EinetParams::init(&plan, LeafFamily::Bernoulli, 5);
        let server = InferenceServer::start_seeded::<DenseEngine>(
            plan,
            LeafFamily::Bernoulli,
            params,
            8,
            Duration::from_millis(5),
            9,
        );
        let mask = vec![1.0f32, 1.0, 0.0, 0.0, 0.0, 0.0];
        let receivers: Vec<_> = (0..12)
            .map(|i| {
                let mut x = vec![0.0f32; nv];
                x[0] = (i % 2) as f32;
                x[1] = 1.0;
                (
                    x.clone(),
                    server.submit_generate(x, mask.clone(), DecodeMode::Sample),
                )
            })
            .collect();
        for (x, rx) in receivers {
            let out = rx.recv().unwrap();
            assert_eq!(out.len(), nv);
            assert_eq!(out[0], x[0], "observed dim resampled");
            assert_eq!(out[1], 1.0, "observed dim resampled");
            for &v in &out {
                assert!(v == 0.0 || v == 1.0, "non-binary completion {v}");
            }
        }
        let stats = server.stop();
        assert_eq!(stats.generated, 12);
        // one compiled plan submitted up front: at least one decode pass
        // must have served several requests at once (see the max_group
        // note in serves_batched_queries_correctly)
        assert!(stats.max_group >= 2, "generation never coalesced");
    }

    #[test]
    fn typed_queries_serve_mpe_and_conditionals() {
        // the unified endpoint: Conditional and Mpe requests batch and
        // answer identically to a direct engine running the same compiled
        // plan
        let nv = 8;
        let plan = LayeredPlan::compile(random_binary_trees(nv, 2, 2, 7), 3);
        let params = EinetParams::init(&plan, LeafFamily::Bernoulli, 7);
        let mut direct = DenseEngine::new(plan.clone(), LeafFamily::Bernoulli, 4);
        let server = InferenceServer::start_seeded::<DenseEngine>(
            plan,
            LeafFamily::Bernoulli,
            params.clone(),
            8,
            Duration::from_millis(3),
            17,
        );
        let mut emask = vec![0.0f32; nv];
        emask[0] = 1.0;
        emask[1] = 1.0;
        let mut qmask = vec![0.0f32; nv];
        qmask[2] = 1.0;
        // conditional: p(x2 | x0, x1)
        let mut x = vec![0.0f32; nv];
        x[0] = 1.0;
        x[2] = 1.0;
        let cond = server.run_query(
            x.clone(),
            Query::Conditional {
                query_mask: qmask.clone(),
                evidence_mask: emask.clone(),
            },
        );
        assert!(cond.row.is_empty(), "score-only query returned a row");
        let qp = Query::Conditional {
            query_mask: qmask,
            evidence_mask: emask.clone(),
        }
        .compile(nv)
        .unwrap();
        let mut want = QueryOutput::default();
        let mut rng = Rng::new(0);
        direct.execute(&params, &qp, &x, 1, &mut rng, &mut want);
        assert_eq!(cond.score.to_bits(), want.scores[0].to_bits());
        // MPE: completion + max-product score, bit-equal to the direct
        // engine (decode draws nothing in Mpe mode)
        let ans = server.mpe(x.clone(), emask.clone());
        let qp = Query::Mpe { mask: emask }.compile(nv).unwrap();
        let mut want = QueryOutput::default();
        direct.execute(&params, &qp, &x, 1, &mut rng, &mut want);
        assert_eq!(ans.score.to_bits(), want.scores[0].to_bits());
        assert_eq!(ans.row, want.rows);
        assert_eq!(ans.row[0], 1.0, "MPE resampled the evidence");
        // Sample{n} does not fit one-row-per-request serving: rejected
        // with a typed cause on the unified endpoint
        let rej = server.submit_query(vec![0.0; nv], Query::Sample { n: 4 });
        assert!(
            matches!(
                rej.recv().expect("typed rejection expected"),
                QueryAnswer::Err(QueryError::UnsupportedSample)
            ),
            "Sample query must be rejected as UnsupportedSample"
        );
        let stats = server.stop();
        assert_eq!(stats.queries, 1);
        assert_eq!(stats.generated, 1);
        assert_eq!(stats.rejected, 1);
        assert_eq!(stats.rej_unsupported, 1);
    }

    #[test]
    fn overload_rejections_are_typed_and_immediate() {
        // max_pending = 0: the admission gate turns every request away
        // before it enters the queue — the unified endpoint sees a typed
        // Overloaded answer, the legacy shim a disconnect, and stop()
        // folds the gate's count into the stats
        let nv = 4;
        let plan = LayeredPlan::compile(random_binary_trees(nv, 2, 1, 6), 2);
        let params = EinetParams::init(&plan, LeafFamily::Bernoulli, 6);
        let server = InferenceServer::start_with::<DenseEngine>(
            plan,
            LeafFamily::Bernoulli,
            params,
            ServerConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
                max_pending: 0,
                ..ServerConfig::default()
            },
        );
        let x = vec![1.0f32, 0.0, 1.0, 0.0];
        let full = server.submit_query(x.clone(), Query::LogLik);
        assert!(
            matches!(
                full.recv().expect("typed rejection expected"),
                QueryAnswer::Err(QueryError::Overloaded)
            ),
            "full-queue submission must be rejected Overloaded"
        );
        let legacy = server.submit(x, vec![1.0f32; nv]);
        assert!(
            legacy.recv().is_err(),
            "legacy shim signals overload by disconnecting"
        );
        let stats = server.stop();
        assert_eq!(stats.queries, 0);
        assert_eq!(stats.rejected, 2);
        assert_eq!(stats.rej_overloaded, 2);
    }

    #[test]
    fn admission_slots_recycle_as_requests_leave_the_system() {
        // a slot is now held from submission until the answer goes out
        // (so max_pending bounds TOTAL in-flight work, not just channel
        // occupancy); both the serve path and the reject path must hand
        // their slot back, or a max_pending=1 server bricks after one
        // request
        let nv = 4;
        let plan = LayeredPlan::compile(random_binary_trees(nv, 2, 1, 9), 2);
        let params = EinetParams::init(&plan, LeafFamily::Bernoulli, 9);
        let server = InferenceServer::start_with::<DenseEngine>(
            plan,
            LeafFamily::Bernoulli,
            params,
            ServerConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
                max_pending: 1,
                ..ServerConfig::default()
            },
        );
        let x = vec![1.0f32, 0.0, 1.0, 0.0];
        for i in 0..3 {
            let ans = server
                .submit_query(x.clone(), Query::LogLik)
                .recv()
                .expect("server must answer");
            assert!(
                matches!(ans, QueryAnswer::Ok(_)),
                "request {i} not served: {ans:?} — slot leaked by the serve path?"
            );
        }
        for i in 0..3 {
            // malformed (short evidence): leaves through the reject path
            let ans = server
                .submit_query(vec![0.0f32; nv - 1], Query::LogLik)
                .recv()
                .expect("server must answer");
            assert!(
                matches!(ans, QueryAnswer::Err(QueryError::Malformed)),
                "reject {i} wrong: {ans:?} — slot leaked by the reject path?"
            );
        }
        let stats = server.stop();
        assert_eq!(stats.queries, 3);
        assert_eq!(stats.rejected, 3);
        assert_eq!(stats.rej_malformed, 3);
        assert_eq!(stats.rej_overloaded, 0, "admission slots were not recycled");
    }

    #[test]
    fn expired_requests_are_rejected_not_served() {
        // deadline = 0: every admitted request has lapsed by the time the
        // dispatcher drains it — a deterministic stand-in for a stalled
        // queue — and is answered Expired instead of served stale
        let nv = 4;
        let plan = LayeredPlan::compile(random_binary_trees(nv, 2, 1, 8), 2);
        let params = EinetParams::init(&plan, LeafFamily::Bernoulli, 8);
        let server = InferenceServer::start_with::<DenseEngine>(
            plan,
            LeafFamily::Bernoulli,
            params,
            ServerConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
                deadline: Duration::ZERO,
                ..ServerConfig::default()
            },
        );
        let x = vec![1.0f32, 0.0, 1.0, 0.0];
        let rx = server.submit_query(x, Query::LogLik);
        assert!(
            matches!(
                rx.recv().expect("typed rejection expected"),
                QueryAnswer::Err(QueryError::Expired)
            ),
            "lapsed request must be rejected Expired"
        );
        let stats = server.stop();
        assert_eq!(stats.queries, 0);
        assert_eq!(stats.rejected, 1);
        assert_eq!(stats.rej_expired, 1);
    }

    #[test]
    fn sharded_server_matches_direct_engine_and_generates() {
        // the segmented serving path answers log-prob queries bit-exactly
        // like a private engine, and generation (forward + sharded
        // decode) respects evidence
        let nv = 10;
        let plan = LayeredPlan::compile(random_binary_trees(nv, 2, 3, 11), 3);
        let params = EinetParams::init(&plan, LeafFamily::Bernoulli, 11);
        let mut direct = DenseEngine::new(plan.clone(), LeafFamily::Bernoulli, 1);
        let server = InferenceServer::start_sharded(
            crate::engine::registry::boxed_build::<DenseEngine>,
            plan,
            LeafFamily::Bernoulli,
            params.clone(),
            3,
            8,
            Duration::from_millis(2),
            13,
        );
        let mask = vec![1.0f32; nv];
        for i in 0..8 {
            let x: Vec<f32> = (0..nv).map(|d| ((i >> d) & 1) as f32).collect();
            let got = server.query(x.clone(), mask.clone());
            let mut want = vec![0.0f32];
            direct.forward(&params, &x, &mask, &mut want);
            assert_eq!(
                got.to_bits(),
                want[0].to_bits(),
                "sharded serving diverged: {got} vs {}",
                want[0]
            );
        }
        let mut gen_mask = vec![0.0f32; nv];
        gen_mask[0] = 1.0;
        gen_mask[1] = 1.0;
        for _ in 0..6 {
            let mut x = vec![0.0f32; nv];
            x[0] = 1.0;
            let out = server.generate(x, gen_mask.clone(), DecodeMode::Sample);
            assert_eq!(out[0], 1.0, "evidence resampled by sharded decode");
            assert_eq!(out[1], 0.0, "evidence resampled by sharded decode");
            for &v in &out {
                assert!(v == 0.0 || v == 1.0, "non-binary completion {v}");
            }
        }
        // MPE rides the same sharded backend: max-product forward across
        // the cut + sel-table backtrack, bit-equal to a direct engine
        let x = vec![1.0f32, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0];
        let ans = server.mpe(x.clone(), gen_mask.clone());
        let qp = Query::Mpe { mask: gen_mask }.compile(nv).unwrap();
        let mut want = QueryOutput::default();
        let mut rng = Rng::new(0);
        let mut direct_cap =
            DenseEngine::new(direct.plan().clone(), LeafFamily::Bernoulli, 8);
        direct_cap.execute(&params, &qp, &x, 1, &mut rng, &mut want);
        assert_eq!(
            ans.score.to_bits(),
            want.scores[0].to_bits(),
            "sharded MPE score diverged"
        );
        assert_eq!(ans.row, want.rows, "sharded MPE completion diverged");
        let stats = server.stop();
        assert_eq!(stats.queries, 8);
        assert_eq!(stats.generated, 7);
    }

    #[test]
    fn registry_named_serving_selects_backends() {
        let nv = 5;
        let plan = LayeredPlan::compile(random_binary_trees(nv, 2, 2, 4), 2);
        let params = EinetParams::init(&plan, LeafFamily::Bernoulli, 4);
        let reg = crate::engine::registry::EngineRegistry::builtin();
        assert!(InferenceServer::start_named(
            &reg,
            "no-such-backend",
            plan.clone(),
            LeafFamily::Bernoulli,
            params.clone(),
            4,
            Duration::from_millis(1),
            0,
        )
        .is_err());
        let mut answers = Vec::new();
        for name in ["dense", "sparse"] {
            let server = InferenceServer::start_named(
                &reg,
                name,
                plan.clone(),
                LeafFamily::Bernoulli,
                params.clone(),
                4,
                Duration::from_millis(1),
                0,
            )
            .unwrap();
            let x = vec![1.0f32, 0.0, 1.0, 0.0, 1.0];
            answers.push(server.query(x, vec![1.0f32; nv]));
            server.stop();
        }
        assert!(
            (answers[0] - answers[1]).abs() < 1e-4,
            "named backends disagree: {answers:?}"
        );
    }

    #[test]
    fn class_queries_serve_single_and_sharded() {
        // Classify / Posterior answers off the server — private engine
        // and sharded pool — are bit-equal to the direct engine running
        // the same compiled plan; against a plain generative circuit the
        // request is rejected typed, not crashed on
        let nv = 8;
        let classes = 3;
        let plan = LayeredPlan::compile(random_binary_trees(nv, 2, 2, 21), 3)
            .with_classes(classes)
            .unwrap();
        let params = EinetParams::init(&plan, LeafFamily::Bernoulli, 21);
        let mut direct = DenseEngine::new(plan.clone(), LeafFamily::Bernoulli, 4);
        let mask = vec![1.0f32; nv];
        let qp_cls = Query::Classify { mask: mask.clone() }.compile(nv).unwrap();
        let qp_post = Query::Posterior { mask: mask.clone() }.compile(nv).unwrap();
        let xs: Vec<Vec<f32>> = (0..4)
            .map(|i| (0..nv).map(|d| (((i * 7 + 3) >> d) & 1) as f32).collect())
            .collect();
        for sharded in [false, true] {
            let server = if sharded {
                InferenceServer::start_sharded(
                    crate::engine::registry::boxed_build::<DenseEngine>,
                    plan.clone(),
                    LeafFamily::Bernoulli,
                    params.clone(),
                    2,
                    8,
                    Duration::from_millis(2),
                    5,
                )
            } else {
                InferenceServer::start::<DenseEngine>(
                    plan.clone(),
                    LeafFamily::Bernoulli,
                    params.clone(),
                    8,
                    Duration::from_millis(2),
                )
            };
            let mut rng = Rng::new(0);
            for x in &xs {
                let mut want = QueryOutput::default();
                direct.execute(&params, &qp_cls, x, 1, &mut rng, &mut want);
                let got = server.classify(x.clone(), mask.clone());
                assert_eq!(
                    got, want.scores[0] as usize,
                    "classify diverged (sharded={sharded})"
                );
                let mut want = QueryOutput::default();
                direct.execute(&params, &qp_post, x, 1, &mut rng, &mut want);
                let post = server.posterior(x.clone(), mask.clone());
                assert_eq!(post.len(), classes);
                for (a, b) in post.iter().zip(&want.scores) {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "posterior diverged (sharded={sharded})"
                    );
                }
                // the posteriors are normalized: logsumexp ~ 0
                let m = post.iter().fold(f32::NEG_INFINITY, |acc, &v| acc.max(v));
                let s: f32 = post.iter().map(|&v| (v - m).exp()).sum();
                assert!((m + s.ln()).abs() < 1e-5, "posterior not normalized");
            }
            server.stop();
        }
        let plain = LayeredPlan::compile(random_binary_trees(nv, 2, 2, 21), 3);
        let pparams = EinetParams::init(&plain, LeafFamily::Bernoulli, 21);
        let server = InferenceServer::start::<DenseEngine>(
            plain,
            LeafFamily::Bernoulli,
            pparams,
            4,
            Duration::from_millis(1),
        );
        let rej = server.submit_classify(xs[0].clone(), mask);
        assert!(
            matches!(
                rej.recv().expect("typed rejection expected"),
                QueryAnswer::Err(QueryError::Malformed)
            ),
            "class query on a classless circuit must be rejected Malformed"
        );
        server.stop();
    }

    #[test]
    fn serves_through_any_engine_backend() {
        // the same router over the sparse baseline produces the same
        // answers — the serving path is engine-agnostic
        let nv = 5;
        let plan = LayeredPlan::compile(random_binary_trees(nv, 2, 2, 3), 3);
        let params = EinetParams::init(&plan, LeafFamily::Bernoulli, 3);
        let mut direct = DenseEngine::new(plan.clone(), LeafFamily::Bernoulli, 1);
        let mask = vec![1.0f32; nv];
        let server = InferenceServer::start::<SparseEngine>(
            plan,
            LeafFamily::Bernoulli,
            params.clone(),
            8,
            Duration::from_millis(2),
        );
        for i in 0..10 {
            let x: Vec<f32> = (0..nv).map(|d| ((i >> d) & 1) as f32).collect();
            let got = server.query(x.clone(), mask.clone());
            let mut want = vec![0.0f32];
            direct.forward(&params, &x, &mask, &mut want);
            assert!((got - want[0]).abs() < 1e-4, "{got} vs {}", want[0]);
        }
        server.stop();
    }
}
