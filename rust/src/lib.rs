//! # einet — Einsum Networks in Rust + JAX + Pallas
//!
//! A reproduction of *"Einsum Networks: Fast and Scalable Learning of
//! Tractable Probabilistic Circuits"* (Peharz et al., ICML 2020) as a
//! three-layer stack:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`): the einsum layer
//!   with the log-einsum-exp trick (Eq. 4/5) and the mixing layer.
//! * **L2** — JAX model (`python/compile/model.py`): the full EiNet
//!   forward pass and EM statistics via autodiff, AOT-lowered to HLO text.
//! * **L3** — this crate: region graphs, structure generators, two
//!   execution engines (dense einsum layout vs the sparse LibSPN/SPFlow
//!   baseline), EM training, tractable inference (marginals, conditionals,
//!   sampling, inpainting), a PJRT runtime for the AOT artifacts, a
//!   multithreaded training coordinator, datasets, clustering, and the
//!   benchmark harness reproducing every table and figure of the paper.
//!
//! See DESIGN.md for the architecture and EXPERIMENTS.md for results.

pub mod bench;
pub mod clustering;
pub mod coordinator;
pub mod data;
pub mod em;
pub mod engine;
pub mod graph;
pub mod infer;
pub mod layers;
pub mod leaves;
pub mod mixture;
pub mod runtime;
pub mod structure;
pub mod util;

pub use engine::dense::{DecodeMode, DenseEngine};
pub use engine::sparse::SparseEngine;
pub use engine::{EinetParams, EmStats};
pub use layers::LayeredPlan;
pub use leaves::LeafFamily;
