//! # einet — Einsum Networks in Rust + JAX + Pallas
//!
//! A reproduction of *"Einsum Networks: Fast and Scalable Learning of
//! Tractable Probabilistic Circuits"* (Peharz et al., ICML 2020) as a
//! three-layer stack:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`): the einsum layer
//!   with the log-einsum-exp trick (Eq. 4/5) and the mixing layer.
//! * **L2** — JAX model (`python/compile/model.py`): the full EiNet
//!   forward pass and EM statistics via autodiff, AOT-lowered to HLO text.
//! * **L3** — this crate: region graphs, structure generators, a unified
//!   execution stack — the [`engine::Engine`] trait over a compiled flat
//!   [`engine::exec::ExecPlan`] IR with a contiguous parameter arena
//!   ([`engine::ParamArena`]), implemented by the dense einsum layout and
//!   the sparse LibSPN/SPFlow baseline — EM training, tractable inference
//!   through the unified [`engine::query::Query`] API (marginals,
//!   conditionals, true max-product MPE, sampling, inpainting — each a
//!   semiring interpretation of the same compiled plan, executed through
//!   [`engine::Engine::execute`]), a PJRT runtime for
//!   the AOT artifacts (feature `pjrt`), a multithreaded training
//!   coordinator with persistent workers, datasets, clustering, and the
//!   benchmark harness reproducing every table and figure of the paper.
//!
//! The innermost einsum reductions run through the batch-blocked,
//! semiring-generic SIMD kernels of [`engine::kernels`] (AVX2 / NEON
//! behind runtime detection, with a bit-identical portable fallback), so
//! likelihood, EM, *and* max-product MPE serving share one fast path.
//!
//! Training, mixtures, inference, and serving are all generic over
//! `E: Engine`, so backends share one code path and new ones (e.g. a
//! PJRT-executed engine) plug in without touching call sites; the
//! runtime [`engine::registry::EngineRegistry`] adds name-based backend
//! selection for the CLI and the server. For models larger than one
//! core's cache, [`engine::exec::PlanPartition`] cuts the compiled plan
//! into scope-disjoint segments and [`coordinator::ShardedPool`] trains,
//! serves, and samples across segment workers that each hold only their
//! [`engine::ArenaShard`] of the parameters.
//!
//! See `docs/ARCHITECTURE.md` for a guided tour of the compile pipeline
//! and `docs/BENCHMARKS.md` for what every `BENCH_*.json` artifact means.
//!
//! # Quickstart
//!
//! Build a RAT structure, train a few EM steps, and answer an exact
//! marginal query through the compiled [`Query`] API:
//!
//! ```
//! use einet::em::{m_step, EmConfig};
//! use einet::structure::random_binary_trees;
//! use einet::util::rng::Rng;
//! use einet::{
//!     DenseEngine, EinetParams, EmStats, Engine, LayeredPlan, LeafFamily,
//!     Query, QueryOutput,
//! };
//!
//! // structure: a small RAT region graph (8 binary variables), K = 4
//! let plan = LayeredPlan::compile(random_binary_trees(8, 2, 2, 0), 4);
//! let family = LeafFamily::Bernoulli;
//! let mut params = EinetParams::init(&plan, family, 0);
//!
//! // a toy batch and a few stochastic EM steps
//! let (bn, nv) = (32usize, 8usize);
//! let mut rng = Rng::new(1);
//! let x: Vec<f32> = (0..bn * nv)
//!     .map(|_| if rng.bernoulli(0.3) { 1.0 } else { 0.0 })
//!     .collect();
//! let mask = vec![1.0f32; nv];
//! let mut engine = DenseEngine::new(plan.clone(), family, bn);
//! let mut logp = vec![0.0f32; bn];
//! let mut last_ll = f64::NEG_INFINITY;
//! for _ in 0..3 {
//!     engine.forward(&params, &x, &mask, &mut logp);
//!     let mut stats = EmStats::zeros_like(&params);
//!     engine.backward(&params, &x, &mask, bn, &mut stats);
//!     last_ll = stats.loglik;
//!     m_step(&mut params, &stats, &EmConfig::default());
//! }
//! assert!(last_ll.is_finite());
//!
//! // exact inference: marginalize out the second half of the variables
//! let mut mmask = vec![1.0f32; nv];
//! for m in mmask.iter_mut().skip(nv / 2) {
//!     *m = 0.0;
//! }
//! let qp = Query::Marginal { mask: mmask }.compile(nv).unwrap();
//! let mut out = QueryOutput::default();
//! engine.execute(&params, &qp, &x, bn, &mut rng, &mut out);
//! assert_eq!(out.scores.len(), bn);
//! assert!(out.scores.iter().all(|s| s.is_finite() && *s <= 1e-4));
//! ```

pub mod bench;
pub mod clustering;
pub mod coordinator;
pub mod data;
pub mod em;
pub mod engine;
pub mod graph;
pub mod infer;
pub mod layers;
pub mod leaves;
pub mod mixture;
pub mod runtime;
pub mod structure;
pub mod util;

pub use coordinator::server::{QueryAnswer, QueryError, QueryOk, ServerConfig};
pub use coordinator::transport::{ShardError, ShardTransport, WorkerConfig};
pub use engine::dense::DenseEngine;
pub use engine::exec::{LayerPlan, PlanPartition, Segment, Semiring, Superblock};
pub use engine::fused::FusedEngine;
pub use engine::query::{ClassReduce, Query, QueryOutput, QueryPass, QueryPlan};
pub use engine::registry::{boxed_build, EngineEntry, EngineFactory, EngineRegistry};
pub use engine::sparse::SparseEngine;
pub use engine::{
    ArenaShard, DecodeMode, EinetParams, EmStats, Engine, ParamArena, ParamLayout,
};
pub use layers::{LayeredPlan, WeightStructure};
pub use leaves::LeafFamily;
pub use util::error::{Error, Result};
