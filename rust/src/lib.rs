//! # einet — Einsum Networks in Rust + JAX + Pallas
//!
//! A reproduction of *"Einsum Networks: Fast and Scalable Learning of
//! Tractable Probabilistic Circuits"* (Peharz et al., ICML 2020) as a
//! three-layer stack:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`): the einsum layer
//!   with the log-einsum-exp trick (Eq. 4/5) and the mixing layer.
//! * **L2** — JAX model (`python/compile/model.py`): the full EiNet
//!   forward pass and EM statistics via autodiff, AOT-lowered to HLO text.
//! * **L3** — this crate: region graphs, structure generators, a unified
//!   execution stack — the [`engine::Engine`] trait over a compiled flat
//!   [`engine::exec::ExecPlan`] IR with a contiguous parameter arena
//!   ([`engine::ParamArena`]), implemented by the dense einsum layout and
//!   the sparse LibSPN/SPFlow baseline — EM training, tractable inference
//!   through the unified [`engine::query::Query`] API (marginals,
//!   conditionals, true max-product MPE, sampling, inpainting — each a
//!   semiring interpretation of the same compiled plan, executed through
//!   [`engine::Engine::execute`]), a PJRT runtime for
//!   the AOT artifacts (feature `pjrt`), a multithreaded training
//!   coordinator with persistent workers, datasets, clustering, and the
//!   benchmark harness reproducing every table and figure of the paper.
//!
//! Training, mixtures, inference, and serving are all generic over
//! `E: Engine`, so backends share one code path and new ones (e.g. a
//! PJRT-executed engine) plug in without touching call sites; the
//! runtime [`engine::registry::EngineRegistry`] adds name-based backend
//! selection for the CLI and the server. For models larger than one
//! core's cache, [`engine::exec::PlanPartition`] cuts the compiled plan
//! into scope-disjoint segments and [`coordinator::ShardedPool`] trains,
//! serves, and samples across segment workers that each hold only their
//! [`engine::ArenaShard`] of the parameters.
//!
//! See DESIGN.md for the architecture and EXPERIMENTS.md for results.

pub mod bench;
pub mod clustering;
pub mod coordinator;
pub mod data;
pub mod em;
pub mod engine;
pub mod graph;
pub mod infer;
pub mod layers;
pub mod leaves;
pub mod mixture;
pub mod runtime;
pub mod structure;
pub mod util;

pub use engine::dense::DenseEngine;
pub use engine::exec::{PlanPartition, Segment, Semiring};
pub use engine::query::{Query, QueryOutput, QueryPass, QueryPlan};
pub use engine::registry::{boxed_build, EngineEntry, EngineFactory, EngineRegistry};
pub use engine::sparse::SparseEngine;
pub use engine::{
    ArenaShard, DecodeMode, EinetParams, EmStats, Engine, ParamArena, ParamLayout,
};
pub use layers::LayeredPlan;
pub use leaves::LeafFamily;
pub use util::error::{Error, Result};
