//! Mixture-of-EiNets (Section 4.2): k-means clusters as mixture
//! components, one EiNet per cluster — step 1 of LearnSPN. A mixture of
//! PCs is again a PC, so marginals/conditionals/sampling stay tractable.
//!
//! The mixture is generic over `E:`[`Engine`]: all components share one
//! compiled engine (plan reuse) of whichever backend the caller picks.

use crate::clustering::kmeans;
use crate::em::{m_step, EmConfig};
use crate::engine::exec::Semiring;
use crate::engine::query::{Query, QueryOutput};
use crate::engine::{DecodeMode, EinetParams, EmStats, Engine};
use crate::layers::LayeredPlan;
use crate::leaves::LeafFamily;
use crate::util::error::Result;
use crate::util::logsumexp::logsumexp_f64;
use crate::util::rng::Rng;

/// One mixture component: a plan-shared EiNet with private parameters.
pub struct Component {
    pub params: EinetParams,
    pub log_weight: f64,
}

/// Reusable buffers for the batched sample/inpaint paths: sized once per
/// call to the engine's capacity and reused across every component group
/// (the gather/forward/decode/scatter loop used to reallocate per group).
#[derive(Default)]
struct MixScratch {
    /// gathered evidence rows of one component group
    xg: Vec<f32>,
    /// per-chunk forward log-probabilities
    logp: Vec<f32>,
    /// per-component block for `sample_batch_into`
    blk: Vec<f32>,
    /// compiled-query results for one component group (scores + rows)
    qout: QueryOutput,
}

/// A mixture of EiNets sharing a single structure (plan + engine reuse).
pub struct EinetMixture<E: Engine> {
    pub family: LeafFamily,
    pub components: Vec<Component>,
    engine: E,
    scratch: MixScratch,
}

/// Training configuration for the image pipeline.
#[derive(Clone, Copy, Debug)]
pub struct MixtureConfig {
    pub num_clusters: usize,
    pub k: usize,
    pub epochs: usize,
    pub batch_size: usize,
    pub em: EmConfig,
    pub seed: u64,
}

impl Default for MixtureConfig {
    fn default() -> Self {
        Self {
            num_clusters: 10,
            k: 8,
            epochs: 5,
            batch_size: 100,
            em: EmConfig {
                step_size: 0.5,
                ..Default::default()
            },
            seed: 0,
        }
    }
}

impl<E: Engine> EinetMixture<E> {
    /// The shared structure plan.
    pub fn plan(&self) -> &LayeredPlan {
        self.engine.plan()
    }

    /// The paper's image pipeline: k-means cluster the data, train one
    /// EiNet per cluster with stochastic EM, use cluster proportions as
    /// mixture coefficients.
    pub fn train(
        plan: LayeredPlan,
        family: LeafFamily,
        data: &[f32],
        n: usize,
        cfg: &MixtureConfig,
        mut progress: impl FnMut(usize, usize, f64),
    ) -> Result<Self> {
        let d = plan.graph.num_vars;
        let od = family.obs_dim();
        let row = d * od;
        assert_eq!(data.len(), n * row);
        let km = kmeans(data, n, row, cfg.num_clusters, 30, cfg.seed);
        let mut engine = E::build(plan.clone(), family, cfg.batch_size);
        let mask = vec![1.0f32; d];
        let mut components = Vec::new();
        for c in 0..cfg.num_clusters {
            // gather this cluster's rows
            let idx: Vec<usize> = (0..n).filter(|&i| km.assignment[i] == c).collect();
            let mut params = EinetParams::init(&plan, family, cfg.seed + 1 + c as u64);
            if !idx.is_empty() {
                let mut cluster = vec![0.0f32; idx.len() * row];
                for (j, &i) in idx.iter().enumerate() {
                    cluster[j * row..(j + 1) * row]
                        .copy_from_slice(&data[i * row..(i + 1) * row]);
                }
                let mut stats = EmStats::zeros_like(&params);
                let mut logp = vec![0.0f32; cfg.batch_size];
                for epoch in 0..cfg.epochs {
                    let mut total = 0.0f64;
                    let mut b0 = 0usize;
                    while b0 < idx.len() {
                        let bn = cfg.batch_size.min(idx.len() - b0);
                        stats.reset();
                        engine.forward(
                            &params,
                            &cluster[b0 * row..(b0 + bn) * row],
                            &mask,
                            &mut logp[..bn],
                        );
                        engine.backward(
                            &params,
                            &cluster[b0 * row..(b0 + bn) * row],
                            &mask,
                            bn,
                            &mut stats,
                        );
                        total += stats.loglik;
                        m_step(&mut params, &stats, &cfg.em);
                        b0 += bn;
                    }
                    progress(c, epoch, total / idx.len() as f64);
                }
            }
            let weight = (km.counts[c].max(1) as f64) / (n as f64);
            components.push(Component {
                params,
                log_weight: weight.ln(),
            });
        }
        // renormalize weights (empty-cluster floor may break normalization)
        let z = logsumexp_f64(
            &components
                .iter()
                .map(|c| c.log_weight)
                .collect::<Vec<_>>(),
        );
        for c in &mut components {
            c.log_weight -= z;
        }
        Ok(Self {
            family,
            components,
            engine,
            scratch: MixScratch::default(),
        })
    }

    /// Mixture log-likelihood per sample (chunked to engine capacity).
    pub fn log_prob(&mut self, x: &[f32], mask: &[f32], out: &mut [f32]) {
        let bn = out.len();
        let row = self.engine.plan().graph.num_vars * self.family.obs_dim();
        let cap = self.engine.batch_capacity();
        let mut acc = vec![f64::NEG_INFINITY; bn];
        let mut b0 = 0usize;
        while b0 < bn {
            let chunk = cap.min(bn - b0);
            let mut logp = vec![0.0f32; chunk];
            for c in 0..self.components.len() {
                self.engine.forward(
                    &self.components[c].params,
                    &x[b0 * row..(b0 + chunk) * row],
                    mask,
                    &mut logp,
                );
                let lw = self.components[c].log_weight;
                for b in 0..chunk {
                    let v = logp[b] as f64 + lw;
                    let a = acc[b0 + b];
                    acc[b0 + b] = if a > v {
                        a + (v - a).exp().ln_1p()
                    } else {
                        v + (a - v).exp().ln_1p()
                    };
                }
            }
            b0 += chunk;
        }
        for b in 0..bn {
            out[b] = acc[b] as f32;
        }
    }

    /// Unconditional samples: draw every sample's component by weight up
    /// front, then ancestral-sample each component's group in ONE batched
    /// [`Engine::sample_batch_into`] call and scatter the rows back. The
    /// group block is engine scratch reused across component groups (and
    /// calls) — no per-group allocation.
    pub fn sample(&mut self, n: usize, rng: &mut Rng, mode: DecodeMode) -> Vec<f32> {
        let d = self.engine.plan().graph.num_vars;
        let od = self.family.obs_dim();
        let row = d * od;
        let weights: Vec<f64> = self
            .components
            .iter()
            .map(|c| c.log_weight.exp())
            .collect();
        let comp: Vec<usize> = (0..n).map(|_| rng.categorical(&weights)).collect();
        let mut out = vec![0.0f32; n * row];
        for c in 0..self.components.len() {
            let idx: Vec<usize> = (0..n).filter(|&s| comp[s] == c).collect();
            if idx.is_empty() {
                continue;
            }
            if self.scratch.blk.len() < idx.len() * row {
                self.scratch.blk.resize(idx.len() * row, 0.0);
            }
            self.engine.sample_batch_into(
                &self.components[c].params,
                idx.len(),
                rng,
                mode,
                &mut self.scratch.blk[..idx.len() * row],
            );
            for (j, &s) in idx.iter().enumerate() {
                out[s * row..(s + 1) * row]
                    .copy_from_slice(&self.scratch.blk[j * row..(j + 1) * row]);
            }
        }
        out
    }

    /// Conditional sampling (inpainting) under the mixture: pick each
    /// sample's component from its posterior given the evidence, then
    /// complete all samples assigned to a component together — one
    /// compiled [`Query::Inpaint`] execution ([`Engine::execute`]: one
    /// batched forward + one batched decode) per (component, chunk)
    /// instead of a forward/decode pair per sample. The gather/result
    /// buffers are engine scratch sized once to capacity and reused
    /// across every component group (and across calls).
    pub fn inpaint(
        &mut self,
        x: &[f32],
        evidence_mask: &[f32],
        bn: usize,
        mode: DecodeMode,
        rng: &mut Rng,
    ) -> Vec<f32> {
        let d = self.engine.plan().graph.num_vars;
        let od = self.family.obs_dim();
        let nc = self.components.len();
        // posterior over components per sample (chunked to capacity)
        let row = d * od;
        let cap = self.engine.batch_capacity();
        if self.scratch.logp.len() < cap {
            self.scratch.logp.resize(cap, 0.0);
        }
        if self.scratch.xg.len() < cap * row {
            self.scratch.xg.resize(cap * row, 0.0);
        }
        let mut post = vec![0.0f64; bn * nc];
        let mut b0 = 0usize;
        while b0 < bn {
            let chunk = cap.min(bn - b0);
            for c in 0..nc {
                self.engine.forward(
                    &self.components[c].params,
                    &x[b0 * row..(b0 + chunk) * row],
                    evidence_mask,
                    &mut self.scratch.logp[..chunk],
                );
                for b in 0..chunk {
                    post[(b0 + b) * nc + c] =
                        self.scratch.logp[b] as f64 + self.components[c].log_weight;
                }
            }
            b0 += chunk;
        }
        // component choice per sample, then group-and-batch the decodes
        let mut weights = vec![0.0f64; nc];
        let comp: Vec<usize> = (0..bn)
            .map(|b| {
                let prow = &post[b * nc..(b + 1) * nc];
                let z = logsumexp_f64(prow);
                for (w, &v) in weights.iter_mut().zip(prow) {
                    *w = (v - z).exp();
                }
                match mode {
                    DecodeMode::Sample => rng.categorical(&weights),
                    DecodeMode::Argmax | DecodeMode::Mpe => {
                        let mut best = 0;
                        for (i, &w) in weights.iter().enumerate() {
                            if w > weights[best] {
                                best = i;
                            }
                        }
                        best
                    }
                }
            })
            .collect();
        // one compiled plan for every component group
        let qp = Query::Inpaint {
            mask: evidence_mask.to_vec(),
            mode,
        }
        .compile(d)
        .expect("invalid evidence mask");
        let mut out = x.to_vec();
        for c in 0..nc {
            let idx: Vec<usize> = (0..bn).filter(|&b| comp[b] == c).collect();
            let mut g0 = 0usize;
            while g0 < idx.len() {
                let chunk = cap.min(idx.len() - g0);
                let group = &idx[g0..g0 + chunk];
                // gather the group's evidence rows into reused scratch,
                // execute the compiled query, scatter the completions
                for (j, &b) in group.iter().enumerate() {
                    self.scratch.xg[j * row..(j + 1) * row]
                        .copy_from_slice(&x[b * row..(b + 1) * row]);
                }
                self.engine.execute(
                    &self.components[c].params,
                    &qp,
                    &self.scratch.xg[..chunk * row],
                    chunk,
                    rng,
                    &mut self.scratch.qout,
                );
                for (j, &b) in group.iter().enumerate() {
                    out[b * row..(b + 1) * row].copy_from_slice(
                        &self.scratch.qout.rows[j * row..(j + 1) * row],
                    );
                }
                g0 += chunk;
            }
        }
        out
    }

    /// Mixture MPE: a mixture of PCs is again a PC, so the exact argmax
    /// completion is `max_c w_c · max_{z, x_u} p_c(x_e, x_u, z)` — one
    /// max-product forward per component scores the candidates, then
    /// each winning component completes its rows with one compiled
    /// [`Query::Mpe`] execution. Returns `(completions, scores)`; the
    /// score includes the mixture weight. Deterministic.
    pub fn mpe(
        &mut self,
        x: &[f32],
        evidence_mask: &[f32],
        bn: usize,
    ) -> (Vec<f32>, Vec<f32>) {
        let d = self.engine.plan().graph.num_vars;
        let od = self.family.obs_dim();
        let row = d * od;
        let nc = self.components.len();
        let cap = self.engine.batch_capacity();
        if self.scratch.logp.len() < cap {
            self.scratch.logp.resize(cap, 0.0);
        }
        if self.scratch.xg.len() < cap * row {
            self.scratch.xg.resize(cap * row, 0.0);
        }
        // winning component per row under the max-product score
        let mut best_c = vec![0usize; bn];
        let mut best_s = vec![f64::NEG_INFINITY; bn];
        let mut b0 = 0usize;
        while b0 < bn {
            let chunk = cap.min(bn - b0);
            for c in 0..nc {
                self.engine.forward_semiring(
                    &self.components[c].params,
                    &x[b0 * row..(b0 + chunk) * row],
                    evidence_mask,
                    &mut self.scratch.logp[..chunk],
                    Semiring::MaxProduct,
                );
                for b in 0..chunk {
                    let v = self.scratch.logp[b] as f64
                        + self.components[c].log_weight;
                    if v > best_s[b0 + b] {
                        best_s[b0 + b] = v;
                        best_c[b0 + b] = c;
                    }
                }
            }
            b0 += chunk;
        }
        // complete each winner's group exactly
        let qp = Query::Mpe {
            mask: evidence_mask.to_vec(),
        }
        .compile(d)
        .expect("invalid evidence mask");
        let mut out = x.to_vec();
        let mut scores = vec![0.0f32; bn];
        let mut rng = Rng::new(0); // the Mpe decode draws nothing
        for c in 0..nc {
            let idx: Vec<usize> = (0..bn).filter(|&b| best_c[b] == c).collect();
            let mut g0 = 0usize;
            while g0 < idx.len() {
                let chunk = cap.min(idx.len() - g0);
                let group = &idx[g0..g0 + chunk];
                for (j, &b) in group.iter().enumerate() {
                    self.scratch.xg[j * row..(j + 1) * row]
                        .copy_from_slice(&x[b * row..(b + 1) * row]);
                }
                self.engine.execute(
                    &self.components[c].params,
                    &qp,
                    &self.scratch.xg[..chunk * row],
                    chunk,
                    &mut rng,
                    &mut self.scratch.qout,
                );
                for (j, &b) in group.iter().enumerate() {
                    out[b * row..(b + 1) * row].copy_from_slice(
                        &self.scratch.qout.rows[j * row..(j + 1) * row],
                    );
                    scores[b] = (self.scratch.qout.scores[j] as f64
                        + self.components[c].log_weight)
                        as f32;
                }
                g0 += chunk;
            }
        }
        (out, scores)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::dense::DenseEngine;
    use crate::structure::random_binary_trees;

    fn two_mode_data(n: usize, nv: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let mut x = vec![0.0f32; n * nv];
        for b in 0..n {
            let mode = rng.bernoulli(0.5);
            for d in 0..nv {
                let p = if mode { 0.9 } else { 0.1 };
                x[b * nv + d] = if rng.bernoulli(p) { 1.0 } else { 0.0 };
            }
        }
        x
    }

    #[test]
    fn mixture_trains_and_scores() {
        let nv = 8;
        let plan = LayeredPlan::compile(random_binary_trees(nv, 2, 2, 0), 3);
        let data = two_mode_data(200, nv, 1);
        let cfg = MixtureConfig {
            num_clusters: 2,
            epochs: 3,
            batch_size: 50,
            ..Default::default()
        };
        let mut mix = EinetMixture::<DenseEngine>::train(
            plan,
            LeafFamily::Bernoulli,
            &data,
            200,
            &cfg,
            |_, _, _| {},
        )
        .unwrap();
        // weights normalized
        let z: f64 = mix.components.iter().map(|c| c.log_weight.exp()).sum();
        assert!((z - 1.0).abs() < 1e-9);
        // scores the training data better than uniform
        let mask = vec![1.0f32; nv];
        let mut lp = vec![0.0f32; 200];
        mix.log_prob(&data, &mask, &mut lp);
        let avg: f64 = lp.iter().map(|&l| l as f64).sum::<f64>() / 200.0;
        assert!(avg > -(nv as f64) * std::f64::consts::LN_2);
    }

    #[test]
    fn mixture_sampling_hits_both_modes() {
        let nv = 6;
        let plan = LayeredPlan::compile(random_binary_trees(nv, 2, 2, 2), 3);
        let data = two_mode_data(300, nv, 3);
        let cfg = MixtureConfig {
            num_clusters: 2,
            epochs: 4,
            batch_size: 64,
            ..Default::default()
        };
        let mut mix = EinetMixture::<DenseEngine>::train(
            plan,
            LeafFamily::Bernoulli,
            &data,
            300,
            &cfg,
            |_, _, _| {},
        )
        .unwrap();
        let mut rng = Rng::new(4);
        let samples = mix.sample(300, &mut rng, DecodeMode::Sample);
        // sample means should be bimodal: average bit density near 0.5
        // overall but individual samples mostly near 0 or 1 density
        let mut extremes = 0usize;
        for s in 0..300 {
            let density: f32 =
                samples[s * nv..(s + 1) * nv].iter().sum::<f32>() / nv as f32;
            if !(0.25..=0.75).contains(&density) {
                extremes += 1;
            }
        }
        assert!(extremes > 150, "samples not bimodal: {extremes}/300");
    }

    #[test]
    fn mixture_mpe_is_deterministic_and_respects_evidence() {
        let nv = 6;
        let plan = LayeredPlan::compile(random_binary_trees(nv, 2, 2, 8), 3);
        let data = two_mode_data(120, nv, 9);
        let cfg = MixtureConfig {
            num_clusters: 2,
            epochs: 2,
            batch_size: 32,
            ..Default::default()
        };
        let mut mix = EinetMixture::<DenseEngine>::train(
            plan,
            LeafFamily::Bernoulli,
            &data,
            120,
            &cfg,
            |_, _, _| {},
        )
        .unwrap();
        let x = vec![1.0f32, 1.0, 1.0, 0.0, 0.0, 0.0];
        let mask = [1.0f32, 1.0, 1.0, 0.0, 0.0, 0.0];
        let (rows_a, scores_a) = mix.mpe(&x, &mask, 1);
        let (rows_b, scores_b) = mix.mpe(&x, &mask, 1);
        assert_eq!(rows_a, rows_b, "MPE must be deterministic");
        assert_eq!(scores_a[0].to_bits(), scores_b[0].to_bits());
        assert_eq!(&rows_a[..3], &[1.0, 1.0, 1.0], "evidence overwritten");
        for &v in &rows_a {
            assert!(v == 0.0 || v == 1.0, "non-mode completion {v}");
        }
        // the winning component's weighted max-product score is what the
        // query reports; it must dominate every other component's
        assert!(scores_a[0].is_finite());
    }

    #[test]
    fn mixture_inpaint_keeps_evidence() {
        let nv = 6;
        let plan = LayeredPlan::compile(random_binary_trees(nv, 2, 2, 5), 3);
        let data = two_mode_data(100, nv, 6);
        let cfg = MixtureConfig {
            num_clusters: 2,
            epochs: 2,
            batch_size: 32,
            ..Default::default()
        };
        let mut mix = EinetMixture::<DenseEngine>::train(
            plan,
            LeafFamily::Bernoulli,
            &data,
            100,
            &cfg,
            |_, _, _| {},
        )
        .unwrap();
        let mut rng = Rng::new(7);
        let x = vec![1.0f32, 1.0, 1.0, 0.0, 0.0, 0.0];
        let mask = [1.0f32, 1.0, 1.0, 0.0, 0.0, 0.0];
        let out = mix.inpaint(&x, &mask, 1, DecodeMode::Sample, &mut rng);
        assert_eq!(&out[..3], &[1.0, 1.0, 1.0]);
        // conditioned on the all-ones half, completion should mostly be ones
        let mut ones = 0;
        for _ in 0..20 {
            let o = mix.inpaint(&x, &mask, 1, DecodeMode::Sample, &mut rng);
            ones += o[3..].iter().filter(|&&v| v > 0.5).count();
        }
        assert!(ones > 30, "conditional inpainting ignored evidence: {ones}/60");
    }
}
