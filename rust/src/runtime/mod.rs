//! PJRT runtime: load and execute the AOT artifacts from the rust hot path.
//!
//! `make artifacts` (python, build-time only) lowers each EiNet config to
//! an id-renumbered **binary HloModuleProto** plus a JSON metadata sidecar
//! (and an `.hlo.txt` for humans); this module loads the proto, compiles
//! it on the PJRT CPU client, and executes it with rust-owned parameters.
//! Python never runs at serve or train time.
//!
//! The PJRT execution path needs the vendored `xla` crate (the
//! xla_extension 0.5.1 closure), which is not available in every build
//! environment, so it is gated behind the `pjrt` cargo feature. Without
//! the feature, artifact *metadata* handling ([`ArtifactMeta`],
//! [`AotParams`]) and discovery still work — only compilation/execution
//! returns a descriptive error. Everything else in the crate (both
//! engines, EM, inference, serving) is independent of this module.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::error::{Context, Result};
use crate::util::json::Json;
use crate::{bail, ensure};

// Interchange format note: artifacts are BINARY HloModuleProto files whose
// instruction/computation ids were renumbered at build time
// (python/compile/hlo_proto_fix.py). Two upstream constraints force this:
//  * jax >= 0.5 emits 64-bit ids, which xla_extension 0.5.1 RET_CHECKs
//    (`proto.id() <= INT_MAX`) at compile time;
//  * the 0.5.1 HLO *text* parser (the usual workaround) keeps process-
//    global state and silently corrupts the second large module parsed in
//    a process — observed as the marginalization-mask parameter being
//    constant-folded to zero. Binary protobuf parsing is stateless.

/// Parameter tensor descriptor from the metadata sidecar.
#[derive(Clone, Debug)]
pub struct ParamDesc {
    pub name: String,
    pub shape: Vec<usize>,
    /// "theta" | "shift" | "w" | "mix"
    pub kind: String,
    /// for kind == "mix": real child count per row (padding-aware M-step)
    pub child_counts: Vec<usize>,
}

impl ParamDesc {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Parsed `<name>.meta.json`.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub family: String,
    pub num_vars: usize,
    pub obs_dim: usize,
    pub stat_dim: usize,
    pub k: usize,
    pub replica: usize,
    pub batch: usize,
    pub params: Vec<ParamDesc>,
    pub file_fwd: String,
    pub file_train: String,
}

impl ArtifactMeta {
    pub fn parse(text: &str) -> Result<Self> {
        let j = Json::parse(text)?;
        let params = j
            .get("params")?
            .as_arr()?
            .iter()
            .map(|p| -> Result<ParamDesc> {
                Ok(ParamDesc {
                    name: p.get("name")?.as_str()?.to_string(),
                    shape: p
                        .get("shape")?
                        .as_arr()?
                        .iter()
                        .map(|v| v.as_usize())
                        .collect::<Result<_>>()?,
                    kind: p
                        .opt("kind")
                        .map(|k| k.as_str().map(str::to_string))
                        .transpose()?
                        .unwrap_or_else(|| "w".to_string()),
                    child_counts: match p.opt("child_counts") {
                        Some(cc) => cc
                            .as_arr()?
                            .iter()
                            .map(|v| v.as_usize())
                            .collect::<Result<_>>()?,
                        None => Vec::new(),
                    },
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let files = j.get("files")?;
        Ok(Self {
            name: j.get("name")?.as_str()?.to_string(),
            family: j.get("family")?.as_str()?.to_string(),
            num_vars: j.get("num_vars")?.as_usize()?,
            obs_dim: j.get("obs_dim")?.as_usize()?,
            stat_dim: j.get("stat_dim")?.as_usize()?,
            k: j.get("k")?.as_usize()?,
            replica: j.get("replica")?.as_usize()?,
            batch: j.get("batch")?.as_usize()?,
            params,
            file_fwd: files.get("fwd")?.as_str()?.to_string(),
            file_train: files.get("train")?.as_str()?.to_string(),
        })
    }

    pub fn load(dir: &Path, name: &str) -> Result<Self> {
        let path = dir.join(format!("{name}.meta.json"));
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }
}

/// Rust-owned parameter state for an AOT artifact, keyed by tensor name.
#[derive(Clone, Debug)]
pub struct AotParams {
    pub tensors: BTreeMap<String, Vec<f32>>,
    pub order: Vec<String>,
}

impl AotParams {
    /// Initialize with the same scheme as `EinetParams::init`: normalized
    /// uniform sum/mixing weights, family-initialized theta, zero shift.
    pub fn init(
        meta: &ArtifactMeta,
        family: crate::leaves::LeafFamily,
        seed: u64,
    ) -> Result<Self> {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(seed);
        let mut tensors = BTreeMap::new();
        let mut order = Vec::new();
        for p in &meta.params {
            let mut v = vec![0.0f32; p.numel()];
            match p.kind.as_str() {
                "theta" => {
                    let s = meta.stat_dim;
                    ensure!(p.shape.last() == Some(&s), "theta stat_dim mismatch");
                    for chunk in v.chunks_mut(s) {
                        family.init_theta(&mut rng, chunk);
                    }
                }
                "shift" => { /* zeros */ }
                "w" => {
                    let kk = p.shape[2] * p.shape[3];
                    for block in v.chunks_mut(kk) {
                        let mut total = 0.0f32;
                        for x in block.iter_mut() {
                            *x = rng.uniform_in(0.01, 1.0) as f32;
                            total += *x;
                        }
                        for x in block.iter_mut() {
                            *x /= total;
                        }
                    }
                }
                "mix" => {
                    let cmax = p.shape[1];
                    ensure!(
                        p.child_counts.len() == p.shape[0],
                        "mix child_counts length mismatch"
                    );
                    for (j, &cn) in p.child_counts.iter().enumerate() {
                        let row = &mut v[j * cmax..j * cmax + cn];
                        let mut total = 0.0f32;
                        for x in row.iter_mut() {
                            *x = rng.uniform_in(0.01, 1.0) as f32;
                            total += *x;
                        }
                        for x in row.iter_mut() {
                            *x /= total;
                        }
                    }
                }
                other => bail!("unknown param kind '{other}'"),
            }
            tensors.insert(p.name.clone(), v);
            order.push(p.name.clone());
        }
        Ok(Self { tensors, order })
    }

    /// Slices in executable input order (params only; append x and mask).
    pub fn input_slices(&self) -> Vec<&[f32]> {
        self.order
            .iter()
            .map(|n| self.tensors[n].as_slice())
            .collect()
    }
}

// ---------------------------------------------------------------------------
// PJRT-backed Runtime / Executable (feature "pjrt")
// ---------------------------------------------------------------------------

#[cfg(feature = "pjrt")]
mod backend {
    use super::*;
    use crate::util::error::Context;
    use crate::{anyhow, bail, ensure};

    /// A compiled executable plus its IO contract.
    pub struct Executable {
        exe: xla::PjRtLoadedExecutable,
        /// expected input shapes: params..., x, mask
        input_shapes: Vec<Vec<usize>>,
        /// number of tuple outputs (1 for fwd; 1 + num params for train)
        pub num_outputs: usize,
    }

    impl Executable {
        /// Execute with f32 inputs in metadata order (params..., x, mask).
        /// Returns each tuple element flattened to `Vec<f32>`.
        pub fn run(&self, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
            ensure!(
                inputs.len() == self.input_shapes.len(),
                "expected {} inputs, got {}",
                self.input_shapes.len(),
                inputs.len()
            );
            let mut literals = Vec::with_capacity(inputs.len());
            for (i, x) in inputs.iter().enumerate() {
                let shape = &self.input_shapes[i];
                let numel: usize = shape.iter().product();
                ensure!(
                    x.len() == numel,
                    "input {i}: expected {numel} elements, got {}",
                    x.len()
                );
                let bytes: &[u8] = unsafe {
                    std::slice::from_raw_parts(x.as_ptr() as *const u8, x.len() * 4)
                };
                literals.push(xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::F32,
                    shape,
                    bytes,
                )?);
            }
            let result = self.exe.execute::<xla::Literal>(&literals)?;
            let tuple = result[0][0].to_literal_sync()?.to_tuple()?;
            ensure!(
                tuple.len() == self.num_outputs,
                "expected {} outputs, got {}",
                self.num_outputs,
                tuple.len()
            );
            tuple
                .into_iter()
                .map(|l| l.to_vec::<f32>().map_err(|e| anyhow!("{e:?}")))
                .collect()
        }
    }

    /// The PJRT CPU runtime: artifact discovery + compilation cache.
    pub struct Runtime {
        client: xla::PjRtClient,
        dir: PathBuf,
    }

    impl Runtime {
        pub fn new(artifact_dir: impl Into<PathBuf>) -> Result<Self> {
            let dir = artifact_dir.into();
            ensure!(
                dir.is_dir(),
                "artifact directory {} missing — run `make artifacts`",
                dir.display()
            );
            Ok(Self {
                client: xla::PjRtClient::cpu()?,
                dir,
            })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Names listed in the artifact manifest.
        pub fn list(&self) -> Result<Vec<String>> {
            super::list_manifest(&self.dir)
        }

        pub fn meta(&self, name: &str) -> Result<ArtifactMeta> {
            ArtifactMeta::load(&self.dir, name)
        }

        /// Compile one entry point ("fwd" or "train") of a named artifact.
        pub fn compile(&self, meta: &ArtifactMeta, tag: &str) -> Result<Executable> {
            let file = match tag {
                "fwd" => &meta.file_fwd,
                "train" => &meta.file_train,
                other => bail!("unknown entry point '{other}'"),
            };
            let path = self.dir.join(file);
            let proto = xla::HloModuleProto::from_proto_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
                /* binary= */ true,
            )
            .with_context(|| format!("loading {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            let mut input_shapes: Vec<Vec<usize>> =
                meta.params.iter().map(|p| p.shape.clone()).collect();
            input_shapes.push(vec![meta.batch, meta.num_vars, meta.obs_dim]);
            input_shapes.push(vec![meta.num_vars]);
            let num_outputs = match tag {
                "fwd" => 1,
                _ => 1 + meta.params.len(),
            };
            Ok(Executable {
                exe,
                input_shapes,
                num_outputs,
            })
        }
    }
}

// ---------------------------------------------------------------------------
// Stub Runtime / Executable (default build, no xla closure)
// ---------------------------------------------------------------------------

#[cfg(not(feature = "pjrt"))]
mod backend {
    use super::*;
    use crate::{bail, ensure};

    const UNAVAILABLE: &str =
        "PJRT execution requires the `pjrt` cargo feature (and the vendored \
         `xla` crate); this build can read artifact metadata but not run \
         executables";

    /// Stub executable: same API, always errors at run time.
    pub struct Executable {
        pub num_outputs: usize,
    }

    impl Executable {
        pub fn run(&self, _inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
            bail!("{UNAVAILABLE}")
        }
    }

    /// Metadata-only runtime: discovery and meta parsing work, compilation
    /// reports the missing feature.
    pub struct Runtime {
        dir: PathBuf,
    }

    impl Runtime {
        pub fn new(artifact_dir: impl Into<PathBuf>) -> Result<Self> {
            let dir = artifact_dir.into();
            ensure!(
                dir.is_dir(),
                "artifact directory {} missing — run `make artifacts`",
                dir.display()
            );
            Ok(Self { dir })
        }

        pub fn platform(&self) -> String {
            "unavailable (built without feature `pjrt`)".to_string()
        }

        pub fn list(&self) -> Result<Vec<String>> {
            super::list_manifest(&self.dir)
        }

        pub fn meta(&self, name: &str) -> Result<ArtifactMeta> {
            ArtifactMeta::load(&self.dir, name)
        }

        pub fn compile(&self, _meta: &ArtifactMeta, _tag: &str) -> Result<Executable> {
            bail!("{UNAVAILABLE}")
        }
    }
}

pub use backend::{Executable, Runtime};

/// Names listed in the artifact manifest (shared by both backends).
fn list_manifest(dir: &Path) -> Result<Vec<String>> {
    let text = std::fs::read_to_string(dir.join("manifest.json"))?;
    Json::parse(&text)?
        .get("configs")?
        .as_arr()?
        .iter()
        .map(|v| v.as_str().map(str::to_string))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    const META_EXAMPLE: &str = r#"{
      "name": "quick", "family": "bernoulli", "num_vars": 4, "obs_dim": 1,
      "stat_dim": 1, "k": 4, "replica": 2, "batch": 8,
      "params": [
        {"name": "theta", "shape": [4, 4, 2, 1], "kind": "theta"},
        {"name": "shift", "shape": [4, 4, 2], "kind": "shift"},
        {"name": "w0", "shape": [4, 4, 4, 4], "kind": "w"},
        {"name": "mix1", "shape": [1, 2], "kind": "mix", "child_counts": [2]}
      ],
      "files": {"fwd": "quick.fwd.hlo.txt", "train": "quick.train.hlo.txt"}
    }"#;

    #[test]
    fn meta_parses() {
        let m = ArtifactMeta::parse(META_EXAMPLE).unwrap();
        assert_eq!(m.num_vars, 4);
        assert_eq!(m.params.len(), 4);
        assert_eq!(m.params[3].child_counts, vec![2]);
        assert_eq!(m.params[0].numel(), 32);
    }

    #[test]
    fn aot_params_init_normalized() {
        let m = ArtifactMeta::parse(META_EXAMPLE).unwrap();
        let p =
            AotParams::init(&m, crate::leaves::LeafFamily::Bernoulli, 0).unwrap();
        let w0 = &p.tensors["w0"];
        for block in w0.chunks(16) {
            let s: f32 = block.iter().sum();
            assert!((s - 1.0).abs() < 1e-4);
        }
        let mix = &p.tensors["mix1"];
        assert!((mix.iter().sum::<f32>() - 1.0).abs() < 1e-4);
        assert!(p.tensors["shift"].iter().all(|&v| v == 0.0));
        assert_eq!(p.input_slices().len(), 4);
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_runtime_reports_missing_feature() {
        let err = Runtime::new("/definitely/not/a/dir").unwrap_err().to_string();
        assert!(err.contains("missing"));
        let exe = Executable { num_outputs: 1 };
        let err = exe.run(&[]).unwrap_err().to_string();
        assert!(err.contains("pjrt"), "unhelpful stub error: {err}");
    }
}
