//! Data pipeline substrate: synthetic stand-ins for the paper's datasets
//! plus real on-disk loaders.
//!
//! No network access is available, so (per DESIGN.md §3) we synthesize:
//!  * [`debd`] — the 20 binary density-estimation datasets (Table 1),
//!    with the real DEBD dimensionalities and split sizes, sampled from
//!    random tree-structured Bayesian networks;
//!  * [`images`] — SVHN-like digit images and CelebA-like face images
//!    (Fig. 4), as procedural renderers with per-sample jitter;
//! plus PPM/PGM image output for qualitative results.
//!
//! Real files load through [`debd::load_dir`] (the canonical DEBD
//! `.data` CSV layout) and [`images::load_labeled`] (the `.eimg`
//! labeled-image container). Both reject malformed input with typed
//! errors — never a panic (`tests/data_loaders.rs` pins the corruption
//! contract) — and callers should validate observations against their
//! circuit's leaf family at load time ([`Split::validate_family`] /
//! [`Dataset::validate_family`]) so out-of-support evidence is caught
//! before it reaches a leaf kernel. The committed fixtures under
//! `rust/fixtures/` (see `gen_fixtures.py`) exercise both loaders
//! offline in tests and the `dataset_bpd` bench.

pub mod debd;
pub mod images;

use crate::ensure;
use crate::leaves::LeafFamily;
use crate::util::error::Result;

/// A dataset split: row-major `[n, num_vars * obs_dim]` f32 matrix.
#[derive(Clone, Debug)]
pub struct Split {
    pub n: usize,
    pub row_len: usize,
    pub data: Vec<f32>,
}

impl Split {
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.row_len..(i + 1) * self.row_len]
    }

    pub fn rows(&self, lo: usize, hi: usize) -> &[f32] {
        &self.data[lo * self.row_len..hi * self.row_len]
    }

    /// Check every observation against `family`'s support
    /// ([`LeafFamily::valid_obs`]): binary values for Bernoulli, in-range
    /// indices for Categorical, `0..=trials` for Binomial, finite values
    /// for Gaussian. `what` labels the split in the error. Run this at
    /// load time — evidence outside the support would index theta out of
    /// bounds or poison training with NaN deep inside a leaf kernel.
    pub fn validate_family(&self, family: LeafFamily, what: &str) -> Result<()> {
        let od = family.obs_dim();
        ensure!(
            od > 0 && self.row_len % od == 0,
            "{what}: row length {} is not a multiple of the leaf \
             family's observation dim {od}",
            self.row_len
        );
        let d = self.row_len / od;
        for i in 0..self.n {
            let row = self.row(i);
            for v in 0..d {
                let obs = &row[v * od..(v + 1) * od];
                ensure!(
                    family.valid_obs(obs),
                    "{what}: row {i}, variable {v}: observation {obs:?} \
                     outside the support of {family:?}"
                );
            }
        }
        Ok(())
    }
}

/// Train/valid/test triple.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub num_vars: usize,
    pub obs_dim: usize,
    pub train: Split,
    pub valid: Split,
    pub test: Split,
}

impl Dataset {
    /// Reject a dataset whose arity disagrees with the circuit's leaf
    /// family — all three splits are checked (see
    /// [`Split::validate_family`]).
    pub fn validate_family(&self, family: LeafFamily) -> Result<()> {
        ensure!(
            self.obs_dim == family.obs_dim(),
            "{}: dataset observation dim {} does not match leaf family \
             {family:?} (obs_dim {})",
            self.name,
            self.obs_dim,
            family.obs_dim()
        );
        self.train
            .validate_family(family, &format!("{} (train)", self.name))?;
        self.valid
            .validate_family(family, &format!("{} (valid)", self.name))?;
        self.test
            .validate_family(family, &format!("{} (test)", self.name))
    }
}

/// Write a PPM (P6) RGB image; `pixels` is `[h, w, 3]` in [0, 1].
pub fn write_ppm(path: &std::path::Path, pixels: &[f32], h: usize, w: usize) -> std::io::Result<()> {
    assert_eq!(pixels.len(), h * w * 3);
    let mut buf = format!("P6\n{w} {h}\n255\n").into_bytes();
    for &v in pixels {
        buf.push((v.clamp(0.0, 1.0) * 255.0).round() as u8);
    }
    std::fs::write(path, buf)
}

/// Write a PGM (P5) grayscale image; `pixels` is `[h, w]` in [0, 1].
pub fn write_pgm(path: &std::path::Path, pixels: &[f32], h: usize, w: usize) -> std::io::Result<()> {
    assert_eq!(pixels.len(), h * w);
    let mut buf = format!("P5\n{w} {h}\n255\n").into_bytes();
    for &v in pixels {
        buf.push((v.clamp(0.0, 1.0) * 255.0).round() as u8);
    }
    std::fs::write(path, buf)
}

/// Tile `n` images (each `[h, w, ch]`, ch in {1, 3}) into one grid image
/// with 1px separators; returns (pixels_rgb, grid_h, grid_w).
pub fn tile_images(
    imgs: &[f32],
    n: usize,
    h: usize,
    w: usize,
    ch: usize,
    cols: usize,
) -> (Vec<f32>, usize, usize) {
    let rows = n.div_ceil(cols);
    let gh = rows * (h + 1) + 1;
    let gw = cols * (w + 1) + 1;
    let mut out = vec![0.25f32; gh * gw * 3];
    for i in 0..n {
        let (r0, c0) = (
            (i / cols) * (h + 1) + 1,
            (i % cols) * (w + 1) + 1,
        );
        for y in 0..h {
            for x in 0..w {
                for c in 0..3 {
                    let src = imgs[((i * h + y) * w + x) * ch + c.min(ch - 1)];
                    out[((r0 + y) * gw + (c0 + x)) * 3 + c] = src;
                }
            }
        }
    }
    (out, gh, gw)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_row_access() {
        let s = Split {
            n: 2,
            row_len: 3,
            data: vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
        };
        assert_eq!(s.row(1), &[4.0, 5.0, 6.0]);
        assert_eq!(s.rows(0, 2).len(), 6);
    }

    #[test]
    fn ppm_and_pgm_write() {
        let dir = std::env::temp_dir();
        let ppm = dir.join("einet_test.ppm");
        write_ppm(&ppm, &vec![0.5; 2 * 2 * 3], 2, 2).unwrap();
        let content = std::fs::read(&ppm).unwrap();
        assert!(content.starts_with(b"P6\n2 2\n255\n"));
        assert_eq!(content.len(), 11 + 12);
        let pgm = dir.join("einet_test.pgm");
        write_pgm(&pgm, &vec![1.5; 4], 2, 2).unwrap(); // clamped
        let content = std::fs::read(&pgm).unwrap();
        assert_eq!(*content.last().unwrap(), 255);
        let _ = std::fs::remove_file(ppm);
        let _ = std::fs::remove_file(pgm);
    }

    #[test]
    fn tiling_dimensions() {
        let imgs = vec![0.5f32; 4 * 2 * 2 * 3];
        let (out, gh, gw) = tile_images(&imgs, 4, 2, 2, 3, 2);
        assert_eq!(gh, 2 * 3 + 1);
        assert_eq!(gw, 2 * 3 + 1);
        assert_eq!(out.len(), gh * gw * 3);
    }
}
