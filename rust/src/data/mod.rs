//! Data pipeline substrate: synthetic stand-ins for the paper's datasets.
//!
//! No network access is available, so (per DESIGN.md §3) we synthesize:
//!  * [`debd`] — the 20 binary density-estimation datasets (Table 1),
//!    with the real DEBD dimensionalities and split sizes, sampled from
//!    random tree-structured Bayesian networks;
//!  * [`images`] — SVHN-like digit images and CelebA-like face images
//!    (Fig. 4), as procedural renderers with per-sample jitter;
//! plus PPM/PGM image output for qualitative results.

pub mod debd;
pub mod images;

/// A dataset split: row-major `[n, num_vars * obs_dim]` f32 matrix.
#[derive(Clone, Debug)]
pub struct Split {
    pub n: usize,
    pub row_len: usize,
    pub data: Vec<f32>,
}

impl Split {
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.row_len..(i + 1) * self.row_len]
    }

    pub fn rows(&self, lo: usize, hi: usize) -> &[f32] {
        &self.data[lo * self.row_len..hi * self.row_len]
    }
}

/// Train/valid/test triple.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub num_vars: usize,
    pub obs_dim: usize,
    pub train: Split,
    pub valid: Split,
    pub test: Split,
}

/// Write a PPM (P6) RGB image; `pixels` is `[h, w, 3]` in [0, 1].
pub fn write_ppm(path: &std::path::Path, pixels: &[f32], h: usize, w: usize) -> std::io::Result<()> {
    assert_eq!(pixels.len(), h * w * 3);
    let mut buf = format!("P6\n{w} {h}\n255\n").into_bytes();
    for &v in pixels {
        buf.push((v.clamp(0.0, 1.0) * 255.0).round() as u8);
    }
    std::fs::write(path, buf)
}

/// Write a PGM (P5) grayscale image; `pixels` is `[h, w]` in [0, 1].
pub fn write_pgm(path: &std::path::Path, pixels: &[f32], h: usize, w: usize) -> std::io::Result<()> {
    assert_eq!(pixels.len(), h * w);
    let mut buf = format!("P5\n{w} {h}\n255\n").into_bytes();
    for &v in pixels {
        buf.push((v.clamp(0.0, 1.0) * 255.0).round() as u8);
    }
    std::fs::write(path, buf)
}

/// Tile `n` images (each `[h, w, ch]`, ch in {1, 3}) into one grid image
/// with 1px separators; returns (pixels_rgb, grid_h, grid_w).
pub fn tile_images(
    imgs: &[f32],
    n: usize,
    h: usize,
    w: usize,
    ch: usize,
    cols: usize,
) -> (Vec<f32>, usize, usize) {
    let rows = n.div_ceil(cols);
    let gh = rows * (h + 1) + 1;
    let gw = cols * (w + 1) + 1;
    let mut out = vec![0.25f32; gh * gw * 3];
    for i in 0..n {
        let (r0, c0) = (
            (i / cols) * (h + 1) + 1,
            (i % cols) * (w + 1) + 1,
        );
        for y in 0..h {
            for x in 0..w {
                for c in 0..3 {
                    let src = imgs[((i * h + y) * w + x) * ch + c.min(ch - 1)];
                    out[((r0 + y) * gw + (c0 + x)) * 3 + c] = src;
                }
            }
        }
    }
    (out, gh, gw)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_row_access() {
        let s = Split {
            n: 2,
            row_len: 3,
            data: vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
        };
        assert_eq!(s.row(1), &[4.0, 5.0, 6.0]);
        assert_eq!(s.rows(0, 2).len(), 6);
    }

    #[test]
    fn ppm_and_pgm_write() {
        let dir = std::env::temp_dir();
        let ppm = dir.join("einet_test.ppm");
        write_ppm(&ppm, &vec![0.5; 2 * 2 * 3], 2, 2).unwrap();
        let content = std::fs::read(&ppm).unwrap();
        assert!(content.starts_with(b"P6\n2 2\n255\n"));
        assert_eq!(content.len(), 11 + 12);
        let pgm = dir.join("einet_test.pgm");
        write_pgm(&pgm, &vec![1.5; 4], 2, 2).unwrap(); // clamped
        let content = std::fs::read(&pgm).unwrap();
        assert_eq!(*content.last().unwrap(), 255);
        let _ = std::fs::remove_file(ppm);
        let _ = std::fs::remove_file(pgm);
    }

    #[test]
    fn tiling_dimensions() {
        let imgs = vec![0.5f32; 4 * 2 * 2 * 3];
        let (out, gh, gw) = tile_images(&imgs, 4, 2, 2, 3, 2);
        assert_eq!(gh, 2 * 3 + 1);
        assert_eq!(gw, 2 * 3 + 1);
        assert_eq!(out.len(), gh * gw * 3);
    }
}
