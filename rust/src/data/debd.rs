//! Synthetic stand-ins for the 20 DEBD binary density-estimation datasets
//! (Table 1): nltcs, msnbc, kdd-2k, plants, jester, audio, netflix,
//! accidents, retail, pumsb-star, dna, kosarek, msweb, book, each-movie,
//! web-kb, reuters-52, 20ng, bbc, ad.
//!
//! The real corpora are not redistributable / not downloadable offline, so
//! each dataset is sampled from a random **tree-structured Bayesian
//! network** over the real variable count, with the real split sizes
//! (capped for tractability). Tree BNs give correlated, learnable structure
//! with non-trivial entropy — exactly what Table 1's claim (EiNet ≈
//! RAT-SPN parity on identical structures) needs from a workload. Every
//! dataset is deterministic in its name-derived seed.

use std::path::Path;

use crate::util::error::Result;
use crate::util::rng::Rng;
use crate::{anyhow, ensure};

use super::{Dataset, Split};

/// (name, num_vars, train_n, valid_n, test_n) — variable counts and split
/// sizes of the canonical DEBD suite (sizes capped at 8k/1k/1k to keep the
/// full 20-dataset Table-1 run tractable on CPU; cap noted in
/// EXPERIMENTS.md).
pub const DEBD_SPECS: [(&str, usize, usize, usize, usize); 20] = [
    ("nltcs", 16, 8000, 1000, 1000),       // real: 16181/2157/3236
    ("msnbc", 17, 8000, 1000, 1000),       // real: 291326/38843/58265
    ("kdd-2k", 64, 8000, 1000, 1000),      // real: 180092/19907/34955
    ("plants", 69, 8000, 1000, 1000),      // real: 17412/2321/3482
    ("jester", 100, 8000, 1000, 1000),     // real: 9000/1000/4116
    ("audio", 100, 8000, 1000, 1000),      // real: 15000/2000/3000
    ("netflix", 100, 8000, 1000, 1000),    // real: 15000/2000/3000
    ("accidents", 111, 8000, 1000, 1000),  // real: 12758/1700/2551
    ("retail", 135, 8000, 1000, 1000),     // real: 22041/2938/4408
    ("pumsb-star", 163, 8000, 1000, 1000), // real: 12262/1635/2452
    ("dna", 180, 1600, 400, 1186),         // real: 1600/400/1186
    ("kosarek", 190, 8000, 1000, 1000),    // real: 33375/4450/6675
    ("msweb", 294, 8000, 1000, 1000),      // real: 29441/3270/5000
    ("book", 500, 8000, 1000, 1000),       // real: 8700/1159/1739
    ("each-movie", 500, 4524, 1002, 591),  // real: 4524/1002/591
    ("web-kb", 839, 2803, 558, 838),       // real: 2803/558/838
    ("reuters-52", 889, 6532, 1028, 1540), // real: 6532/1028/1540
    ("20ng", 910, 8000, 1000, 1000),       // real: 11293/3764/3764
    ("bbc", 1058, 1670, 225, 330),         // real: 1670/225/330
    ("ad", 1556, 2461, 327, 491),          // real: 2461/327/491
];

/// A random tree-structured Bayesian network over binary variables.
pub struct TreeBn {
    pub num_vars: usize,
    /// parent of each variable (parent[root] == usize::MAX)
    pub parent: Vec<usize>,
    /// topological sampling order
    pub order: Vec<usize>,
    /// root marginal p(x_root = 1)
    pub p_root: f64,
    /// conditional p(x = 1 | parent = 0) / p(x = 1 | parent = 1)
    pub p_given: Vec<[f64; 2]>,
}

impl TreeBn {
    /// Random tree with random CPTs, biased toward sparse activations
    /// (most DEBD datasets are sparse binary matrices).
    pub fn random(num_vars: usize, rng: &mut Rng, sparsity: f64) -> Self {
        let mut parent = vec![usize::MAX; num_vars];
        let mut order = vec![0usize];
        for v in 1..num_vars {
            parent[v] = rng.below(v); // random attachment: random tree
            order.push(v);
        }
        let mut p_given = vec![[0.0; 2]; num_vars];
        for p in p_given.iter_mut() {
            // keep a strong parent-child coupling so there is structure
            let lo = (rng.uniform() * sparsity).clamp(0.02, 0.98);
            let hi = (lo + 0.3 + 0.6 * rng.uniform()).clamp(0.02, 0.98);
            *p = if rng.bernoulli(0.5) { [lo, hi] } else { [hi, lo] };
        }
        Self {
            num_vars,
            parent,
            order,
            p_root: 0.2 + 0.6 * rng.uniform(),
            p_given,
        }
    }

    /// Draw one joint sample into `row` (length num_vars).
    pub fn sample(&self, rng: &mut Rng, row: &mut [f32]) {
        for &v in &self.order {
            let p = if self.parent[v] == usize::MAX {
                self.p_root
            } else {
                let pa = row[self.parent[v]] as usize;
                self.p_given[v][pa]
            };
            row[v] = if rng.bernoulli(p) { 1.0 } else { 0.0 };
        }
    }

    /// Exact log-likelihood of a row (ground-truth reference for tests).
    pub fn log_prob(&self, row: &[f32]) -> f64 {
        let mut lp = 0.0;
        for &v in &self.order {
            let p = if self.parent[v] == usize::MAX {
                self.p_root
            } else {
                self.p_given[v][row[self.parent[v]] as usize]
            };
            lp += if row[v] > 0.5 { p.ln() } else { (1.0 - p).ln() };
        }
        lp
    }
}

fn name_seed(name: &str) -> u64 {
    // FNV-1a
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Generate one named DEBD-like dataset (deterministic per name).
pub fn load(name: &str) -> Option<Dataset> {
    let &(n, num_vars, tr, va, te) = DEBD_SPECS.iter().find(|s| s.0 == name)?;
    let mut rng = Rng::new(name_seed(n));
    let bn = TreeBn::random(num_vars, &mut rng, 0.5);
    let mut make = |count: usize| {
        let mut data = vec![0.0f32; count * num_vars];
        for i in 0..count {
            bn.sample(&mut rng, &mut data[i * num_vars..(i + 1) * num_vars]);
        }
        Split {
            n: count,
            row_len: num_vars,
            data,
        }
    };
    Some(Dataset {
        name: n.to_string(),
        num_vars,
        obs_dim: 1,
        train: make(tr),
        valid: make(va),
        test: make(te),
    })
}

/// All 20 dataset names in Table-1 order.
pub fn all_names() -> Vec<&'static str> {
    DEBD_SPECS.iter().map(|s| s.0).collect()
}

/// Parse one DEBD split body (the canonical `.data` format: one row per
/// line, comma-separated small non-negative integers). `what` labels the
/// source in error messages. Every malformation — a non-integer token, a
/// ragged row, an empty file — is a typed [`crate::util::error::Error`],
/// never a panic: these files arrive from disk, not from this process.
pub fn parse_split(text: &str, what: &str) -> Result<Split> {
    let mut data: Vec<f32> = Vec::new();
    let mut row_len: Option<usize> = None;
    let mut n = 0usize;
    for (ln, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let start = data.len();
        for tok in line.split(',') {
            let tok = tok.trim();
            let v: u32 = tok.parse().map_err(|_| {
                anyhow!(
                    "{what}:{}: token {tok:?} is not a non-negative integer",
                    ln + 1
                )
            })?;
            data.push(v as f32);
        }
        let width = data.len() - start;
        match row_len {
            None => row_len = Some(width),
            Some(w) => ensure!(
                width == w,
                "{what}:{}: row has {width} values, expected {w}",
                ln + 1
            ),
        }
        n += 1;
    }
    let row_len = row_len.ok_or_else(|| anyhow!("{what}: no data rows"))?;
    Ok(Split { n, row_len, data })
}

/// Load one `.data` split file from disk (see [`parse_split`]). A
/// missing or unreadable file is a typed error carrying the path.
pub fn load_split_file(path: &Path) -> Result<Split> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow!("cannot read DEBD split {}: {e}", path.display()))?;
    parse_split(&text, &path.display().to_string())
}

/// Load a DEBD-format dataset from disk: `<dir>/<name>.train.data`,
/// `.valid.data`, `.test.data` (the canonical DEBD repository layout).
/// The three splits must agree on the variable count. Callers that know
/// their circuit's leaf family should follow up with
/// [`Dataset::validate_family`] so an arity mismatch (e.g. categorical
/// values under Bernoulli leaves) is rejected at load time instead of
/// panicking inside a leaf kernel.
pub fn load_dir(dir: &Path, name: &str) -> Result<Dataset> {
    let part = |split: &str| load_split_file(&dir.join(format!("{name}.{split}.data")));
    let train = part("train")?;
    let valid = part("valid")?;
    let test = part("test")?;
    ensure!(
        valid.row_len == train.row_len && test.row_len == train.row_len,
        "DEBD splits of {name} disagree on variable count: \
         train {} / valid {} / test {}",
        train.row_len,
        valid.row_len,
        test.row_len
    );
    Ok(Dataset {
        name: name.to_string(),
        num_vars: train.row_len,
        obs_dim: 1,
        train,
        valid,
        test,
    })
}

/// Synthetic Gaussian-noise data for the Fig. 3 / Fig. 6 efficiency
/// benchmarks (the paper: N = 2000 samples, D = 512 dimensions).
pub fn gaussian_noise(n: usize, num_vars: usize, seed: u64) -> Split {
    let mut rng = Rng::new(seed);
    let data = (0..n * num_vars)
        .map(|_| rng.normal() as f32)
        .collect();
    Split {
        n,
        row_len: num_vars,
        data,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_cover_20_names() {
        assert_eq!(DEBD_SPECS.len(), 20);
        assert_eq!(all_names().len(), 20);
        assert!(all_names().contains(&"nltcs"));
        assert!(all_names().contains(&"ad"));
    }

    #[test]
    fn load_is_deterministic() {
        let a = load("nltcs").unwrap();
        let b = load("nltcs").unwrap();
        assert_eq!(a.train.data, b.train.data);
        assert_eq!(a.num_vars, 16);
        assert_eq!(a.train.n, 8000);
    }

    #[test]
    fn unknown_name_is_none() {
        assert!(load("nope").is_none());
    }

    #[test]
    fn datasets_differ_across_names() {
        let a = load("nltcs").unwrap();
        let b = load("msnbc").unwrap();
        assert_ne!(
            &a.train.data[..16.min(a.train.data.len())],
            &b.train.data[..16.min(b.train.data.len())]
        );
    }

    #[test]
    fn tree_bn_has_structure() {
        // mutual information between a child and its parent should be
        // clearly positive (data is not independent noise)
        let mut rng = Rng::new(0);
        let bn = TreeBn::random(10, &mut rng, 0.5);
        let child = (1..10).find(|&v| bn.parent[v] != usize::MAX).unwrap();
        let parent = bn.parent[child];
        let n = 20_000;
        let mut row = vec![0.0f32; 10];
        let (mut c11, mut c1x, mut cx1) = (0usize, 0usize, 0usize);
        for _ in 0..n {
            bn.sample(&mut rng, &mut row);
            if row[child] > 0.5 {
                c1x += 1;
            }
            if row[parent] > 0.5 {
                cx1 += 1;
            }
            if row[child] > 0.5 && row[parent] > 0.5 {
                c11 += 1;
            }
        }
        let p11 = c11 as f64 / n as f64;
        let p1 = c1x as f64 / n as f64;
        let p2 = cx1 as f64 / n as f64;
        assert!(
            (p11 - p1 * p2).abs() > 0.02,
            "child/parent nearly independent: {p11} vs {}",
            p1 * p2
        );
    }

    #[test]
    fn bn_log_prob_is_normalized_small() {
        let mut rng = Rng::new(1);
        let bn = TreeBn::random(8, &mut rng, 0.5);
        let mut total = 0.0f64;
        let mut row = vec![0.0f32; 8];
        for state in 0..256usize {
            for d in 0..8 {
                row[d] = ((state >> d) & 1) as f32;
            }
            total += bn.log_prob(&row).exp();
        }
        assert!((total - 1.0).abs() < 1e-9, "total {total}");
    }

    #[test]
    fn gaussian_noise_shape_and_moments() {
        let s = gaussian_noise(2000, 32, 0);
        assert_eq!(s.data.len(), 2000 * 32);
        let mean: f32 = s.data.iter().sum::<f32>() / s.data.len() as f32;
        assert!(mean.abs() < 0.02);
    }
}
