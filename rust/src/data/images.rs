//! Procedural image generators standing in for SVHN and CelebA (Fig. 4).
//!
//! * [`svhn_like`] — house-number-style digit images: a seven-segment digit
//!   glyph rendered at jittered position/scale on a colored background with
//!   per-sample hue, brightness and noise variation.
//! * [`celeba_like`] — face-like images: an elliptical skin-tone face on a
//!   background, with eyes, brows, mouth and hair region, jittered in
//!   geometry and color.
//!
//! Both return `[n, h*w, channels]` rows in [0, 1], matching the paper's
//! normalize-by-255, no-other-preprocessing pipeline, and are deterministic
//! per seed. They exercise the identical modeling path (PD structure over
//! pixels, factorized Gaussian leaves over channels, k-means mixture).

use std::path::Path;

use crate::util::error::Result;
use crate::util::rng::Rng;
use crate::{anyhow, ensure};

use super::Split;

/// Seven-segment layout: segments (a..g) as (x0, y0, x1, y1) in a unit box.
///           a
///          f b
///           g
///          e c
///           d
const SEGMENTS: [(f32, f32, f32, f32); 7] = [
    (0.2, 0.05, 0.8, 0.15), // a
    (0.7, 0.10, 0.85, 0.50), // b
    (0.7, 0.50, 0.85, 0.90), // c
    (0.2, 0.85, 0.8, 0.95), // d
    (0.15, 0.50, 0.3, 0.90), // e
    (0.15, 0.10, 0.3, 0.50), // f
    (0.2, 0.45, 0.8, 0.55), // g
];

/// Which segments light up per digit 0-9.
const DIGIT_SEGMENTS: [u8; 10] = [
    0b0111111, // 0: a b c d e f
    0b0000110, // 1: b c
    0b1011011, // 2: a b d e g
    0b1001111, // 3: a b c d g
    0b1100110, // 4: b c f g
    0b1101101, // 5: a c d f g
    0b1111101, // 6: a c d e f g
    0b0000111, // 7: a b c
    0b1111111, // 8
    0b1101111, // 9: a b c d f g
];

/// SVHN-like RGB digit images: returns rows of `[h*w, 3]`, plus the digit
/// labels (useful for clustering sanity checks).
pub fn svhn_like(n: usize, h: usize, w: usize, seed: u64) -> (Split, Vec<u8>) {
    let mut rng = Rng::new(seed);
    let mut data = vec![0.0f32; n * h * w * 3];
    let mut labels = vec![0u8; n];
    for i in 0..n {
        let digit = rng.below(10);
        labels[i] = digit as u8;
        let img = &mut data[i * h * w * 3..(i + 1) * h * w * 3];
        render_digit(img, h, w, digit, &mut rng);
    }
    (
        Split {
            n,
            row_len: h * w * 3,
            data,
        },
        labels,
    )
}

fn render_digit(img: &mut [f32], h: usize, w: usize, digit: usize, rng: &mut Rng) {
    // background: dark-ish random hue
    let bg = [
        (0.1 + 0.3 * rng.uniform()) as f32,
        (0.1 + 0.3 * rng.uniform()) as f32,
        (0.1 + 0.3 * rng.uniform()) as f32,
    ];
    // foreground: bright, contrasting
    let fg = [
        (0.6 + 0.4 * rng.uniform()) as f32,
        (0.6 + 0.4 * rng.uniform()) as f32,
        (0.6 + 0.4 * rng.uniform()) as f32,
    ];
    // glyph box jitter
    let cx = 0.5 + 0.08 * (rng.uniform() as f32 - 0.5);
    let cy = 0.5 + 0.08 * (rng.uniform() as f32 - 0.5);
    let scale = 0.75 + 0.2 * rng.uniform() as f32;
    let segs = DIGIT_SEGMENTS[digit];
    let noise = 0.03f32;
    for y in 0..h {
        for x in 0..w {
            // map pixel into glyph-local unit coordinates
            let u = ((x as f32 + 0.5) / w as f32 - cx) / scale + 0.5;
            let v = ((y as f32 + 0.5) / h as f32 - cy) / scale + 0.5;
            let mut lit = false;
            if (0.0..1.0).contains(&u) && (0.0..1.0).contains(&v) {
                for (s, seg) in SEGMENTS.iter().enumerate() {
                    if segs & (1 << s) != 0
                        && u >= seg.0
                        && u <= seg.2
                        && v >= seg.1
                        && v <= seg.3
                    {
                        lit = true;
                        break;
                    }
                }
            }
            let px = &mut img[(y * w + x) * 3..(y * w + x) * 3 + 3];
            for c in 0..3 {
                let base = if lit { fg[c] } else { bg[c] };
                px[c] = (base + noise * rng.normal() as f32).clamp(0.0, 1.0);
            }
        }
    }
}

/// CelebA-like RGB face images (centered face blob with features).
pub fn celeba_like(n: usize, h: usize, w: usize, seed: u64) -> Split {
    let mut rng = Rng::new(seed);
    let mut data = vec![0.0f32; n * h * w * 3];
    for i in 0..n {
        let img = &mut data[i * h * w * 3..(i + 1) * h * w * 3];
        render_face(img, h, w, &mut rng);
    }
    Split {
        n,
        row_len: h * w * 3,
        data,
    }
}

fn render_face(img: &mut [f32], h: usize, w: usize, rng: &mut Rng) {
    let bg = [
        (0.2 + 0.6 * rng.uniform()) as f32,
        (0.2 + 0.6 * rng.uniform()) as f32,
        (0.3 + 0.6 * rng.uniform()) as f32,
    ];
    // skin tone family
    let tone = 0.45 + 0.45 * rng.uniform() as f32;
    let skin = [tone, tone * 0.78, tone * 0.62];
    let hair = [
        (0.05 + 0.4 * rng.uniform()) as f32,
        (0.05 + 0.3 * rng.uniform()) as f32,
        (0.05 + 0.25 * rng.uniform()) as f32,
    ];
    let cx = 0.5 + 0.05 * (rng.uniform() as f32 - 0.5);
    let cy = 0.52 + 0.05 * (rng.uniform() as f32 - 0.5);
    let rx = 0.27 + 0.05 * rng.uniform() as f32;
    let ry = 0.36 + 0.05 * rng.uniform() as f32;
    let eye_y = cy - 0.08;
    let eye_dx = 0.11 + 0.02 * rng.uniform() as f32;
    let mouth_y = cy + 0.18;
    let noise = 0.025f32;
    for y in 0..h {
        for x in 0..w {
            let u = (x as f32 + 0.5) / w as f32;
            let v = (y as f32 + 0.5) / h as f32;
            let du = (u - cx) / rx;
            let dv = (v - cy) / ry;
            let in_face = du * du + dv * dv <= 1.0;
            let in_hair = {
                let dvh = (v - (cy - 0.12)) / (ry * 1.15);
                let duh = (u - cx) / (rx * 1.2);
                duh * duh + dvh * dvh <= 1.0 && v < cy - 0.18
            };
            let mut col = if in_hair {
                hair
            } else if in_face {
                skin
            } else {
                bg
            };
            if in_face {
                // eyes
                for side in [-1.0f32, 1.0] {
                    let ex = cx + side * eye_dx;
                    let dd = (u - ex) * (u - ex) / (0.035 * 0.035)
                        + (v - eye_y) * (v - eye_y) / (0.022 * 0.022);
                    if dd <= 1.0 {
                        col = [0.08, 0.07, 0.07];
                    }
                }
                // mouth
                let dm = (u - cx) * (u - cx) / (0.09 * 0.09)
                    + (v - mouth_y) * (v - mouth_y) / (0.02 * 0.02);
                if dm <= 1.0 {
                    col = [0.6, 0.2, 0.22];
                }
            }
            let px = &mut img[(y * w + x) * 3..(y * w + x) * 3 + 3];
            for c in 0..3 {
                px[c] = (col[c] + noise * rng.normal() as f32).clamp(0.0, 1.0);
            }
        }
    }
}

/// Grayscale variant of the digit renderer (used by the AOT e2e config,
/// which models 8x8 single-channel images).
pub fn digits_gray(n: usize, h: usize, w: usize, seed: u64) -> (Split, Vec<u8>) {
    let (rgb, labels) = svhn_like(n, h, w, seed);
    let mut data = vec![0.0f32; n * h * w];
    for i in 0..n * h * w {
        data[i] = (rgb.data[i * 3] + rgb.data[i * 3 + 1] + rgb.data[i * 3 + 2]) / 3.0;
    }
    (
        Split {
            n,
            row_len: h * w,
            data,
        },
        labels,
    )
}

// ---------------------------------------------------------------------------
// labeled-image container (.eimg)
// ---------------------------------------------------------------------------

/// Magic prefix of the `.eimg` labeled-image container.
pub const EIMG_MAGIC: &[u8; 4] = b"EIMG";

/// A labeled image set loaded from an `.eimg` file: pixel rows, one
/// `u8` class label per image, and the class count the file declares.
#[derive(Clone, Debug)]
pub struct LabeledImages {
    /// `[n, h*w*channels]` rows in [0, 1] (stored bytes / 255)
    pub split: Split,
    pub labels: Vec<u8>,
    pub h: usize,
    pub w: usize,
    pub channels: usize,
    pub classes: usize,
}

/// Parse an `.eimg` byte buffer: 4-byte magic `EIMG`, five little-endian
/// `u32`s (`n`, `h`, `w`, `channels`, `classes`), `n` label bytes (each
/// `< classes`), then `n*h*w*channels` pixel bytes (value / 255 → f32).
/// Every malformation — short header, wrong magic, a label out of range,
/// truncated pixels, trailing bytes — is a typed error naming `what`,
/// never a panic (mirrors the checkpoint codec's corruption contract).
pub fn parse_labeled(bytes: &[u8], what: &str) -> Result<LabeledImages> {
    ensure!(
        bytes.len() >= 4 + 5 * 4,
        "{what}: truncated header ({} bytes, need {})",
        bytes.len(),
        4 + 5 * 4
    );
    ensure!(
        &bytes[..4] == EIMG_MAGIC,
        "{what}: bad magic {:?} (not an .eimg file)",
        &bytes[..4]
    );
    let u32_at = |i: usize| {
        let o = 4 + i * 4;
        u32::from_le_bytes([bytes[o], bytes[o + 1], bytes[o + 2], bytes[o + 3]]) as usize
    };
    let (n, h, w, channels, classes) =
        (u32_at(0), u32_at(1), u32_at(2), u32_at(3), u32_at(4));
    ensure!(
        n > 0 && h > 0 && w > 0 && channels > 0,
        "{what}: degenerate shape n={n} h={h} w={w} channels={channels}"
    );
    ensure!(classes > 0, "{what}: class count must be >= 1");
    let row_len = h
        .checked_mul(w)
        .and_then(|px| px.checked_mul(channels))
        .ok_or_else(|| anyhow!("{what}: image shape overflows"))?;
    let body = &bytes[4 + 5 * 4..];
    let need = n
        .checked_mul(row_len)
        .and_then(|p| p.checked_add(n))
        .ok_or_else(|| anyhow!("{what}: payload size overflows"))?;
    ensure!(
        body.len() == need,
        "{what}: payload carries {} bytes, expected {need} \
         ({n} labels + {n}x{row_len} pixels)",
        body.len()
    );
    let labels = body[..n].to_vec();
    if let Some((i, &y)) = labels.iter().enumerate().find(|(_, &y)| y as usize >= classes)
    {
        return Err(anyhow!(
            "{what}: label {y} of image {i} is outside the declared \
             {classes} classes"
        ));
    }
    let data: Vec<f32> = body[n..].iter().map(|&b| b as f32 / 255.0).collect();
    Ok(LabeledImages {
        split: Split {
            n,
            row_len,
            data,
        },
        labels,
        h,
        w,
        channels,
        classes,
    })
}

/// Load an `.eimg` labeled-image file (see [`parse_labeled`]). A missing
/// or unreadable file is a typed error carrying the path.
pub fn load_labeled(path: &Path) -> Result<LabeledImages> {
    let bytes = std::fs::read(path)
        .map_err(|e| anyhow!("cannot read image file {}: {e}", path.display()))?;
    parse_labeled(&bytes, &path.display().to_string())
}

/// Write an `.eimg` file: `split` rows in [0, 1] are quantized to bytes
/// (`round(v * 255)`), one label per row, `labels[i] < classes`. The
/// committed benchmark fixtures and the corruption tests both go through
/// this writer, so reader and writer cannot drift.
pub fn save_labeled(
    path: &Path,
    split: &Split,
    labels: &[u8],
    h: usize,
    w: usize,
    channels: usize,
    classes: usize,
) -> Result<()> {
    ensure!(
        split.row_len == h * w * channels,
        "row length {} does not match shape {h}x{w}x{channels}",
        split.row_len
    );
    ensure!(
        labels.len() == split.n,
        "{} labels for {} images",
        labels.len(),
        split.n
    );
    ensure!(classes > 0, "class count must be >= 1");
    if let Some((i, &y)) = labels.iter().enumerate().find(|(_, &y)| y as usize >= classes)
    {
        return Err(anyhow!(
            "label {y} of image {i} is outside the declared {classes} classes"
        ));
    }
    let mut buf = Vec::with_capacity(4 + 5 * 4 + split.n + split.data.len());
    buf.extend_from_slice(EIMG_MAGIC);
    for v in [split.n, h, w, channels, classes] {
        ensure!(v <= u32::MAX as usize, "field {v} overflows the u32 header");
        buf.extend_from_slice(&(v as u32).to_le_bytes());
    }
    buf.extend_from_slice(labels);
    buf.extend(
        split
            .data
            .iter()
            .map(|&v| (v.clamp(0.0, 1.0) * 255.0).round() as u8),
    );
    std::fs::write(path, buf)
        .map_err(|e| anyhow!("cannot write image file {}: {e}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn svhn_like_shape_and_range() {
        let (s, labels) = svhn_like(10, 16, 16, 0);
        assert_eq!(s.data.len(), 10 * 16 * 16 * 3);
        assert_eq!(labels.len(), 10);
        assert!(s.data.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn deterministic_per_seed() {
        let (a, la) = svhn_like(3, 8, 8, 7);
        let (b, lb) = svhn_like(3, 8, 8, 7);
        assert_eq!(a.data, b.data);
        assert_eq!(la, lb);
        let (c, _) = svhn_like(3, 8, 8, 8);
        assert_ne!(a.data, c.data);
    }

    #[test]
    fn digits_are_distinguishable() {
        // mean image of digit 1 should differ clearly from digit 8
        let (s, labels) = svhn_like(400, 16, 16, 1);
        let dim = 16 * 16 * 3;
        let mut mean1 = vec![0.0f64; dim];
        let mut mean8 = vec![0.0f64; dim];
        let (mut n1, mut n8) = (0, 0);
        for i in 0..400 {
            let img = s.row(i);
            match labels[i] {
                1 => {
                    n1 += 1;
                    for d in 0..dim {
                        mean1[d] += img[d] as f64;
                    }
                }
                8 => {
                    n8 += 1;
                    for d in 0..dim {
                        mean8[d] += img[d] as f64;
                    }
                }
                _ => {}
            }
        }
        assert!(n1 > 5 && n8 > 5);
        let dist: f64 = mean1
            .iter()
            .zip(&mean8)
            .map(|(a, b)| (a / n1 as f64 - b / n8 as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        assert!(dist > 0.5, "digit means too close: {dist}");
    }

    #[test]
    fn celeba_like_has_face_structure() {
        let s = celeba_like(5, 32, 32, 2);
        assert_eq!(s.data.len(), 5 * 32 * 32 * 3);
        // center pixel should usually differ from corner (face vs bg)
        let mut diffs = 0;
        for i in 0..5 {
            let img = s.row(i);
            let center = (16 * 32 + 16) * 3;
            let corner = 0;
            if (img[center] - img[corner]).abs() > 0.05 {
                diffs += 1;
            }
        }
        assert!(diffs >= 3);
    }

    #[test]
    fn gray_conversion() {
        let (g, _) = digits_gray(2, 8, 8, 3);
        assert_eq!(g.data.len(), 2 * 64);
        assert!(g.data.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }
}
