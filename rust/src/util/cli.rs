//! A small command-line argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments,
//! typed accessors with defaults, and auto-generated usage text.

use std::collections::BTreeMap;

use crate::util::error::Result;
use crate::{anyhow, bail};

/// Declarative option spec used for usage text and validation.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

/// Parsed arguments.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse raw argv (without the program name) against a spec.
    pub fn parse(argv: &[String], spec: &[OptSpec]) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let sp = spec
                    .iter()
                    .find(|s| s.name == key)
                    .ok_or_else(|| anyhow!("unknown option --{key}"))?;
                if sp.is_flag {
                    if inline_val.is_some() {
                        bail!("--{key} is a flag and takes no value");
                    }
                    out.flags.push(key);
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| anyhow!("--{key} requires a value"))?
                            .clone(),
                    };
                    out.options.insert(key, val);
                }
            } else {
                out.positional.push(arg.clone());
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get<'a>(&'a self, name: &str, spec: &[OptSpec]) -> Option<String> {
        if let Some(v) = self.options.get(name) {
            return Some(v.clone());
        }
        spec.iter()
            .find(|s| s.name == name)
            .and_then(|s| s.default.map(str::to_string))
    }

    pub fn get_usize(&self, name: &str, spec: &[OptSpec]) -> Result<usize> {
        let v = self
            .get(name, spec)
            .ok_or_else(|| anyhow!("missing --{name}"))?;
        v.parse()
            .map_err(|e| anyhow!("--{name}={v} is not an integer: {e}"))
    }

    pub fn get_f64(&self, name: &str, spec: &[OptSpec]) -> Result<f64> {
        let v = self
            .get(name, spec)
            .ok_or_else(|| anyhow!("missing --{name}"))?;
        v.parse()
            .map_err(|e| anyhow!("--{name}={v} is not a number: {e}"))
    }

    pub fn get_str(&self, name: &str, spec: &[OptSpec]) -> Result<String> {
        self.get(name, spec)
            .ok_or_else(|| anyhow!("missing --{name}"))
    }
}

/// Render usage text for a subcommand.
pub fn usage(cmd: &str, about: &str, spec: &[OptSpec]) -> String {
    let mut out = format!("{cmd} — {about}\n\noptions:\n");
    for s in spec {
        let default = s
            .default
            .map(|d| format!(" (default: {d})"))
            .unwrap_or_default();
        let kind = if s.is_flag { "" } else { " <value>" };
        out.push_str(&format!(
            "  --{}{}\n      {}{}\n",
            s.name, kind, s.help, default
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> Vec<OptSpec> {
        vec![
            OptSpec {
                name: "k",
                help: "vector length",
                default: Some("10"),
                is_flag: false,
            },
            OptSpec {
                name: "out",
                help: "output path",
                default: None,
                is_flag: false,
            },
            OptSpec {
                name: "verbose",
                help: "chatty",
                default: None,
                is_flag: true,
            },
        ]
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_kinds() {
        let a = Args::parse(
            &sv(&["train", "--k", "20", "--verbose", "--out=x.json"]),
            &spec(),
        )
        .unwrap();
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.get_usize("k", &spec()).unwrap(), 20);
        assert!(a.flag("verbose"));
        assert_eq!(a.get_str("out", &spec()).unwrap(), "x.json");
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&sv(&[]), &spec()).unwrap();
        assert_eq!(a.get_usize("k", &spec()).unwrap(), 10);
        assert!(a.get("out", &spec()).is_none());
    }

    #[test]
    fn rejects_unknown_and_missing_value() {
        assert!(Args::parse(&sv(&["--nope"]), &spec()).is_err());
        assert!(Args::parse(&sv(&["--k"]), &spec()).is_err());
        assert!(Args::parse(&sv(&["--verbose=1"]), &spec()).is_err());
    }

    #[test]
    fn type_errors_are_reported() {
        let a = Args::parse(&sv(&["--k", "abc"]), &spec()).unwrap();
        assert!(a.get_usize("k", &spec()).is_err());
    }

    #[test]
    fn usage_mentions_options() {
        let u = usage("train", "train a model", &spec());
        assert!(u.contains("--k") && u.contains("default: 10"));
    }
}
