//! Deterministic pseudo-random number generation.
//!
//! The offline crate registry ships no `rand`; we implement SplitMix64 (for
//! seeding) and Xoshiro256++ (the workhorse), both well-studied generators
//! with public-domain reference implementations. All randomized components
//! of the library (structure generation, data synthesis, initialization,
//! sampling) take explicit seeds so that every experiment is reproducible.

/// SplitMix64: used to expand a single `u64` seed into generator state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256++: fast, high-quality 64-bit PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64, as recommended by the xoshiro authors.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut s = [0u64; 4];
        for v in s.iter_mut() {
            *v = sm.next_u64();
        }
        // avoid the all-zero state (astronomically unlikely, but cheap)
        if s == [0, 0, 0, 0] {
            s[0] = 1;
        }
        Self { s }
    }

    /// Derive an independent stream (for per-worker / per-dataset rngs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Counter-based child stream: a pure function of `(salt, idx)` — no
    /// generator state is consumed, so any execution order that derives
    /// the same `(salt, idx)` pairs reproduces the same draws. This is
    /// what makes batched/sharded sampling order-independent: the decode
    /// executors draw one `salt` per call ([`Rng::next_u64`] on the
    /// caller's rng) and then give every (sample, region) visit its own
    /// `from_stream(salt, key)` stream.
    #[inline]
    pub fn from_stream(salt: u64, idx: u64) -> Rng {
        Rng::new(salt ^ idx.wrapping_mul(0xA24BAED4963EE407))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53 bits of mantissa.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n) via Lemire's multiply-shift (unbiased
    /// enough for our purposes; n is tiny relative to 2^64).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (cached second value omitted to keep
    /// the generator state a pure function of the draw count).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-300 {
                let u2 = self.uniform();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Bernoulli draw with success probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Sample an index from unnormalized non-negative weights. Degenerate
    /// total mass falls back to index 0 before any draw — see
    /// [`Rng::categorical_f32`] for why this cannot be an assert (the
    /// mixture decode path reaches the same collapsed-posterior input).
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        if !(total > 0.0) || !total.is_finite() {
            return 0;
        }
        let mut u = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Sample an index from f32 weights (hot path helper). A degenerate
    /// total (zero, NaN, or infinite — e.g. a decode posterior collapsed
    /// by evidence that underflowed every mixture component to -inf)
    /// falls back to index 0 deterministically, before any RNG draw, so
    /// a shared server thread survives pathological-but-finite requests
    /// and the stream stays identical for valid inputs. This cannot be a
    /// debug assert: extreme-but-finite evidence passes every boundary
    /// validation yet still collapses the posterior, so degenerate mass
    /// here is reachable input, not necessarily an internal bug.
    pub fn categorical_f32(&mut self, weights: &[f32]) -> usize {
        let total: f32 = weights.iter().sum();
        if !(total > 0.0) || !total.is_finite() {
            return 0;
        }
        let mut u = (self.uniform() as f32) * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn categorical_f32_degenerate_mass_falls_back_to_zero() {
        let mut rng = Rng::new(1);
        assert_eq!(rng.categorical_f32(&[0.0, 0.0]), 0);
        assert_eq!(rng.categorical_f32(&[f32::NAN, 1.0]), 0);
        assert_eq!(rng.categorical_f32(&[f32::INFINITY, 1.0]), 0);
        assert_eq!(rng.categorical(&[0.0f64, 0.0]), 0);
        assert_eq!(rng.categorical(&[f64::NAN, 1.0]), 0);
        // a valid draw still lands in the support
        assert_eq!(rng.categorical_f32(&[0.0, 1.0]), 1);
        assert_eq!(rng.categorical(&[0.0f64, 1.0]), 1);
    }

    #[test]
    fn deterministic_by_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Rng::new(7);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let i = r.below(10);
            assert!(i < 10);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(5);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio {ratio}");
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(1);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn counter_streams_are_pure_and_distinct() {
        // same (salt, idx) => same stream, regardless of when/where built
        let mut a = Rng::from_stream(42, 7);
        let mut b = Rng::from_stream(42, 7);
        for _ in 0..16 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        // different idx => different stream
        let mut c = Rng::from_stream(42, 8);
        assert_ne!(a.next_u64(), c.next_u64());
        // different salt => different stream
        let mut d = Rng::from_stream(43, 7);
        let mut e = Rng::from_stream(42, 7);
        assert_ne!(d.next_u64(), e.next_u64());
    }
}
