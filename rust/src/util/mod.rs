//! Shared substrates: RNG, log-domain math, bitsets, statistics, JSON,
//! CLI parsing, timing, and buffer accounting.
//!
//! Everything here is hand-rolled because the offline crate registry only
//! carries the `xla` dependency closure — see DESIGN.md §3 (Substitutions).

pub mod bitset;
pub mod error;
pub mod fastmath;
pub mod cli;
pub mod json;
pub mod logsumexp;
pub mod rng;
pub mod stats;

use std::time::Instant;

/// Wall-clock timer with split support.
pub struct Timer {
    start: Instant,
}

impl Default for Timer {
    fn default() -> Self {
        Self::new()
    }
}

impl Timer {
    pub fn new() -> Self {
        Self {
            start: Instant::now(),
        }
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }

    pub fn reset(&mut self) {
        self.start = Instant::now();
    }
}

/// Leveled stderr logger controlled by the `EINET_LOG` env var
/// (`error|warn|info|debug`, default `info`).
#[derive(Clone, Copy, PartialEq, PartialOrd, Debug)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

pub fn log_level() -> Level {
    match std::env::var("EINET_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        _ => Level::Info,
    }
}

#[macro_export]
macro_rules! log_at {
    ($lvl:expr, $tag:expr, $($arg:tt)*) => {
        if $crate::util::log_level() >= $lvl {
            eprintln!("[{}] {}", $tag, format!($($arg)*));
        }
    };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::log_at!($crate::util::Level::Info, "info", $($arg)*) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::log_at!($crate::util::Level::Debug, "debug", $($arg)*) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => { $crate::log_at!($crate::util::Level::Warn, "warn", $($arg)*) };
}

/// Byte counts for the Fig. 3 / Fig. 6 memory-proxy: every engine reports
/// the f32 buffers it keeps alive, mirroring the paper's GPU peak-memory
/// comparison (explicit product materialization is exactly the term that
/// separates the layouts).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct MemFootprint {
    /// parameter storage (weights, leaf params), bytes
    pub params: usize,
    /// activation storage (per-batch log-prob buffers), bytes
    pub activations: usize,
    /// scratch storage (temporaries the engine must keep allocated),
    /// in particular explicit product nodes in the sparse layout
    pub scratch: usize,
}

impl MemFootprint {
    pub fn total(&self) -> usize {
        self.params + self.activations + self.scratch
    }

    pub fn total_mib(&self) -> f64 {
        self.total() as f64 / (1024.0 * 1024.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotonic() {
        let t = Timer::new();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(t.elapsed_ms() >= 4.0);
    }

    #[test]
    fn footprint_total() {
        let m = MemFootprint {
            params: 100,
            activations: 50,
            scratch: 25,
        };
        assert_eq!(m.total(), 175);
        assert!(m.total_mib() > 0.0);
    }
}
