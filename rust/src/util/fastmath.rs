//! Scalar fast transcendental approximations (reference prototypes).
//!
//! The dense engine spends a large share of its time in `exp` (2K per
//! node, Eq. 4) and `ln` (K per node); the sparse baseline spends K^3 in
//! `exp`. These branch-free polynomial approximations (~1e-7 relative
//! error, exact at 0) were evaluated as a candidate optimization.
//!
//! **Measured outcome (EXPERIMENTS.md §Perf): no speedup as scalar
//! calls** — one-at-a-time, the call overhead matches libm's exp/ln.
//! The win only materializes vectorized: the *shipped* fast-math tier
//! lives in [`crate::engine::kernels`] ([`vexp`]/[`vln`] under
//! [`MathTier::Fast`]), which evaluates the same polynomial shapes 8
//! lanes at a time (AVX2; 4 on NEON) with a documented ULP-bounded
//! accuracy contract and IEEE edge semantics. This module stays as the
//! tested scalar reference the kernel lanes were derived from.
//!
//! [`vexp`]: crate::engine::kernels::vexp
//! [`vln`]: crate::engine::kernels::vln
//! [`MathTier::Fast`]: crate::engine::kernels::MathTier::Fast

/// exp(x) via 2^(x log2 e) = 2^k * 2^f with a degree-6 polynomial for
/// 2^f on f in [0, 1). Max relative error ~1e-5 (Taylor tail plus
/// argument-reduction rounding). Inputs below -87 flush to 0, above +88
/// saturate (instead of overflowing to inf).
#[inline]
pub fn fast_exp(x: f32) -> f32 {
    if x < -87.0 {
        return 0.0;
    }
    let x = x.min(88.0);
    let t = x * std::f32::consts::LOG2_E;
    let kf = t.floor();
    let f = t - kf;
    // 2^f = exp(f ln 2): Taylor coefficients ln2^n / n!
    let p = 1.0
        + f * (0.693_147_2
            + f * (0.240_226_51
                + f * (0.055_504_11
                    + f * (0.009_618_13
                        + f * (0.001_333_36 + f * 0.000_154_03)))));
    let bits = ((kf as i32 + 127) << 23) as u32;
    f32::from_bits(bits) * p
}

/// ln(x) via exponent extraction + atanh-style polynomial on the
/// mantissa. Max absolute error ~3e-8 for normal positive inputs.
/// Returns -inf for x <= 0 (matching `f32::ln` on 0; NaN inputs get NaN).
#[inline]
pub fn fast_ln(x: f32) -> f32 {
    if x <= 0.0 {
        return if x == 0.0 { f32::NEG_INFINITY } else { f32::NAN };
    }
    let bits = x.to_bits();
    let e = ((bits >> 23) as i32 - 127) as f32;
    // mantissa m in [1, 2)
    let m = f32::from_bits((bits & 0x007F_FFFF) | 0x3F80_0000);
    // map to s = (m - sqrt2/... ) use u = (m-1)/(m+1), ln m = 2 atanh(u)
    let u = (m - 1.0) / (m + 1.0);
    let u2 = u * u;
    let lnm = 2.0 * u
        * (1.0
            + u2 * (0.333_333_3
                + u2 * (0.2 + u2 * (0.142_857_15 + u2 * (0.111_111_1 + u2 * 0.090_909_1)))));
    e * std::f32::consts::LN_2 + lnm
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exp_accuracy_over_range() {
        for i in -1000..1000 {
            let x = i as f32 * 0.05;
            let want = x.exp();
            let got = fast_exp(x);
            let rel = (got - want).abs() / want.max(1e-30);
            assert!(rel < 2e-5, "x={x}: {got} vs {want} (rel {rel})");
        }
    }

    #[test]
    fn exp_extremes() {
        assert_eq!(fast_exp(-100.0), 0.0);
        assert!(fast_exp(100.0).is_finite());
        assert_eq!(fast_exp(0.0), 1.0);
    }

    #[test]
    fn ln_accuracy_over_range() {
        for i in 1..4000 {
            let x = i as f32 * 0.01;
            let want = x.ln();
            let got = fast_ln(x);
            assert!(
                (got - want).abs() < 1e-6 * (1.0 + want.abs()),
                "x={x}: {got} vs {want}"
            );
        }
        // small and large magnitudes
        for x in [1e-30f32, 1e-10, 1e10, 1e30] {
            let (got, want) = (fast_ln(x), x.ln());
            assert!((got - want).abs() < 1e-5 * want.abs(), "x={x}");
        }
    }

    #[test]
    fn ln_edge_cases() {
        assert_eq!(fast_ln(0.0), f32::NEG_INFINITY);
        assert!(fast_ln(-1.0).is_nan());
        assert_eq!(fast_ln(1.0), 0.0);
    }

    #[test]
    fn exp_ln_round_trip() {
        for i in -50..50 {
            let x = i as f32 * 0.3;
            let rt = fast_ln(fast_exp(x));
            assert!((rt - x).abs() < 2e-5 * (1.0 + x.abs()), "x={x} rt={rt}");
        }
    }
}
