//! Minimal JSON reader/writer for the artifact metadata contract.
//!
//! `serde` is unavailable in the offline registry, and the only JSON this
//! library touches is the small, machine-generated `*.meta.json` sidecars
//! written by python/compile/aot.py plus our own experiment reports — a
//! hand-rolled recursive-descent parser is entirely sufficient and keeps
//! the dependency closure at just `xla` + `anyhow`.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::util::error::Result;
use crate::{anyhow, bail};

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            s: text.as_bytes(),
            i: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.s.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------
    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m
                .get(key)
                .ok_or_else(|| anyhow!("missing key '{key}'")),
            _ => bail!("not an object"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("not a non-negative integer: {n}");
        }
        Ok(n as usize)
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    /// Render compactly (stable key order via BTreeMap).
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors for report writing.
pub fn obj(entries: Vec<(&str, Json)>) -> Json {
    Json::Obj(
        entries
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect(),
    )
}

pub fn arr(items: Vec<Json>) -> Json {
    Json::Arr(items)
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

struct Parser<'a> {
    s: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.s.len() && self.s[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.s
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!(
                "expected '{}' at byte {}, found '{}'",
                c as char,
                self.i,
                self.s[self.i] as char
            );
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.s[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.s.len()
            && matches!(self.s[self.i], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.s[start..self.i])?;
        Ok(Json::Num(txt.parse::<f64>().map_err(|e| {
            anyhow!("bad number '{txt}' at byte {start}: {e}")
        })?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.s.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.s[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            out.push(
                                char::from_u32(cp).unwrap_or('\u{fffd}'),
                            );
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                c => {
                    // re-decode multi-byte utf-8 sequences
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let width = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        let end = (start + width).min(self.s.len());
                        out.push_str(std::str::from_utf8(&self.s[start..end])?);
                        self.i = end;
                    }
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                c => bail!("expected ',' or ']' found '{}'", c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                c => bail!("expected ',' or '}}' found '{}'", c as char),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_artifact_meta_shape() {
        let text = r#"{
          "name": "quick_d4", "k": 4, "batch": 8,
          "params": [{"name": "theta", "shape": [4, 4, 2, 1]}],
          "inputs": ["theta", "x", "mask"]
        }"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("name").unwrap().as_str().unwrap(), "quick_d4");
        assert_eq!(v.get("k").unwrap().as_usize().unwrap(), 4);
        let params = v.get("params").unwrap().as_arr().unwrap();
        let shape: Vec<usize> = params[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|j| j.as_usize().unwrap())
            .collect();
        assert_eq!(shape, vec![4, 4, 2, 1]);
    }

    #[test]
    fn round_trips() {
        let text = r#"{"a": [1, 2.5, -3e2], "b": "hi\n\"there\"", "c": null, "d": true}"#;
        let v = Json::parse(text).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""café ☕""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café ☕");
    }

    #[test]
    fn builders() {
        let v = obj(vec![
            ("x", num(1.0)),
            ("ys", arr(vec![s("a"), s("b")])),
        ]);
        assert_eq!(v.to_string(), r#"{"x":1,"ys":["a","b"]}"#);
    }
}
