//! Minimal error substrate: a drop-in replacement for the `anyhow` API
//! surface this crate uses (`Result`, `Error`, `Context`, and the
//! `anyhow!` / `bail!` / `ensure!` macros), hand-rolled because the build
//! environment has no crate registry (DESIGN.md §3, Substitutions).
//!
//! The implementation is a message string plus an optional context chain;
//! `{e}` and `{e:#}` both render the full chain.

use std::fmt;

/// Crate-wide result type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A string-backed error with a context chain (outermost first).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg(m: impl fmt::Display) -> Self {
        Self {
            chain: vec![m.to_string()],
        }
    }

    /// Prepend a higher-level context message.
    pub fn wrap(mut self, ctx: impl fmt::Display) -> Self {
        self.chain.insert(0, ctx.to_string());
        self
    }

    /// The outermost message.
    pub fn message(&self) -> &str {
        &self.chain[0]
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

// Note: `Error` deliberately does NOT implement `std::error::Error`, so
// this blanket conversion does not collide with `impl From<T> for T`.
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e.to_string())
    }
}

/// `.context(..)` / `.with_context(|| ..)` on `Result` and `Option`.
pub trait Context<T> {
    fn context(self, ctx: impl fmt::Display) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, ctx: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error::msg(e).wrap(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(e).wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, ctx: impl fmt::Display) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)+) => {
        $crate::util::error::Error::msg(format!($($arg)+))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return Err($crate::util::error::Error::msg(format!($($arg)+)))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::util::error::Error::msg(format!(
                "condition failed: {}",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::util::error::Error::msg(format!($($arg)+)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("inner {}", 42)
    }

    #[test]
    fn macros_and_context_chain() {
        let e = fails().context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner 42");
        assert_eq!(e.message(), "outer");
        let e2: Error = anyhow!("direct {x}", x = 7);
        assert_eq!(format!("{e2}"), "direct 7");
    }

    #[test]
    fn ensure_variants() {
        fn check(v: usize) -> Result<usize> {
            ensure!(v < 10, "too big: {v}");
            ensure!(v != 3);
            Ok(v)
        }
        assert!(check(2).is_ok());
        assert!(check(12).unwrap_err().to_string().contains("too big"));
        assert!(check(3).unwrap_err().to_string().contains("v != 3"));
    }

    #[test]
    fn std_error_converts() {
        fn io() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        assert!(io().is_err());
        let n: Result<u32> = "x".parse::<u32>().map_err(Error::from);
        assert!(n.is_err());
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        assert!(v.context("missing").is_err());
        assert_eq!(Some(5u32).with_context(|| "no").unwrap(), 5);
    }
}
