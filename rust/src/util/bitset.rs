//! Fixed-capacity bitsets for variable scopes.
//!
//! Scope operations (union, intersection-empty checks) dominate structure
//! generation and validation; a u64-word bitset keeps them O(D/64).

/// A growable bitset over variable indices.
#[derive(Clone, PartialEq, Eq, Hash, Debug, Default)]
pub struct BitSet {
    words: Vec<u64>,
}

impl BitSet {
    pub fn new(capacity: usize) -> Self {
        Self {
            words: vec![0; capacity.div_ceil(64)],
        }
    }

    pub fn from_indices(capacity: usize, idx: impl IntoIterator<Item = usize>) -> Self {
        let mut s = Self::new(capacity);
        for i in idx {
            s.insert(i);
        }
        s
    }

    /// All variables 0..n set.
    pub fn full(n: usize) -> Self {
        let mut s = Self::new(n);
        for i in 0..n {
            s.insert(i);
        }
        s
    }

    pub fn insert(&mut self, i: usize) {
        let w = i / 64;
        if w >= self.words.len() {
            self.words.resize(w + 1, 0);
        }
        self.words[w] |= 1u64 << (i % 64);
    }

    pub fn contains(&self, i: usize) -> bool {
        let w = i / 64;
        w < self.words.len() && self.words[w] & (1u64 << (i % 64)) != 0
    }

    pub fn len(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.words.iter().all(|&w| w == 0)
    }

    pub fn union(&self, other: &BitSet) -> BitSet {
        let n = self.words.len().max(other.words.len());
        let mut out = BitSet { words: vec![0; n] };
        for (i, w) in out.words.iter_mut().enumerate() {
            *w = self.words.get(i).copied().unwrap_or(0)
                | other.words.get(i).copied().unwrap_or(0);
        }
        out
    }

    pub fn intersects(&self, other: &BitSet) -> bool {
        self.words
            .iter()
            .zip(&other.words)
            .any(|(a, b)| a & b != 0)
    }

    pub fn union_with(&mut self, other: &BitSet) {
        if other.words.len() > self.words.len() {
            self.words.resize(other.words.len(), 0);
        }
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a |= b;
        }
    }

    /// Iterate set indices in increasing order.
    pub fn iter(&self) -> impl Iterator<Item = usize> + '_ {
        self.words.iter().enumerate().flat_map(|(wi, &w)| {
            (0..64).filter_map(move |b| {
                if w & (1u64 << b) != 0 {
                    Some(wi * 64 + b)
                } else {
                    None
                }
            })
        })
    }

    pub fn to_vec(&self) -> Vec<usize> {
        self.iter().collect()
    }

    pub fn min(&self) -> Option<usize> {
        self.iter().next()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_contains_len() {
        let mut s = BitSet::new(128);
        assert!(s.is_empty());
        s.insert(0);
        s.insert(63);
        s.insert(64);
        s.insert(127);
        assert_eq!(s.len(), 4);
        assert!(s.contains(63) && s.contains(64));
        assert!(!s.contains(1));
    }

    #[test]
    fn union_and_intersects() {
        let a = BitSet::from_indices(100, [1, 5, 70]);
        let b = BitSet::from_indices(100, [2, 5, 90]);
        let u = a.union(&b);
        assert_eq!(u.to_vec(), vec![1, 2, 5, 70, 90]);
        assert!(a.intersects(&b));
        let c = BitSet::from_indices(100, [3, 4]);
        assert!(!a.intersects(&c));
    }

    #[test]
    fn full_and_iter_order() {
        let f = BitSet::full(70);
        assert_eq!(f.len(), 70);
        let v = f.to_vec();
        assert_eq!(v[0], 0);
        assert_eq!(*v.last().unwrap(), 69);
        assert!(v.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn equality_is_content_based() {
        let a = BitSet::from_indices(10, [1, 2]);
        let b = BitSet::from_indices(10, [2, 1]);
        assert_eq!(a, b);
    }

    #[test]
    fn grows_on_insert() {
        let mut s = BitSet::new(1);
        s.insert(1000);
        assert!(s.contains(1000));
    }
}
