//! Summary statistics and the one-sided Welch t-test used by Table 1.
//!
//! The paper declares EiNet/RAT-SPN log-likelihood differences significant
//! via a one-sided t-test at p = 0.05; we reproduce that decision rule.
//! The p-value requires the CDF of Student's t, computed through the
//! regularized incomplete beta function (continued-fraction evaluation,
//! Numerical-Recipes style) — implemented here from scratch since no stats
//! crate is available offline.

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Unbiased sample variance.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64
}

pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// ln Gamma via the Lanczos approximation (g=7, n=9).
pub fn ln_gamma(x: f64) -> f64 {
    const COEF: [f64; 9] = [
        0.99999999999980993,
        676.5203681218851,
        -1259.1392167224028,
        771.32342877765313,
        -176.61502916214059,
        12.507343278686905,
        -0.13857109526572012,
        9.9843695780195716e-6,
        1.5056327351493116e-7,
    ];
    if x < 0.5 {
        // reflection formula
        let pi = std::f64::consts::PI;
        return (pi / (pi * x).sin()).ln() - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = COEF[0];
    let t = x + 7.5;
    for (i, &c) in COEF.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

/// Regularized incomplete beta I_x(a, b) by Lentz continued fraction.
pub fn betainc(a: f64, b: f64, x: f64) -> f64 {
    assert!((0.0..=1.0).contains(&x), "x out of range");
    if x == 0.0 || x == 1.0 {
        return x;
    }
    let ln_front =
        ln_gamma(a + b) - ln_gamma(a) - ln_gamma(b) + a * x.ln() + b * (1.0 - x).ln();
    // use the symmetry relation for faster convergence (non-recursive to
    // avoid the boundary case x == (a+1)/(a+b+2) ping-ponging)
    if x < (a + 1.0) / (a + b + 2.0) {
        ln_front.exp() * beta_cf(a, b, x) / a
    } else {
        1.0 - ln_front.exp() * beta_cf(b, a, 1.0 - x) / b
    }
}

fn beta_cf(a: f64, b: f64, x: f64) -> f64 {
    const MAX_ITER: usize = 300;
    const EPS: f64 = 1e-14;
    const TINY: f64 = 1e-300;
    let qab = a + b;
    let qap = a + 1.0;
    let qam = a - 1.0;
    let mut c = 1.0;
    let mut d = 1.0 - qab * x / qap;
    if d.abs() < TINY {
        d = TINY;
    }
    d = 1.0 / d;
    let mut h = d;
    for m in 1..=MAX_ITER {
        let m = m as f64;
        let m2 = 2.0 * m;
        // even step
        let aa = m * (b - m) * x / ((qam + m2) * (a + m2));
        d = 1.0 + aa * d;
        if d.abs() < TINY {
            d = TINY;
        }
        c = 1.0 + aa / c;
        if c.abs() < TINY {
            c = TINY;
        }
        d = 1.0 / d;
        h *= d * c;
        // odd step
        let aa = -(a + m) * (qab + m) * x / ((a + m2) * (qap + m2));
        d = 1.0 + aa * d;
        if d.abs() < TINY {
            d = TINY;
        }
        c = 1.0 + aa / c;
        if c.abs() < TINY {
            c = TINY;
        }
        d = 1.0 / d;
        let del = d * c;
        h *= del;
        if (del - 1.0).abs() < EPS {
            break;
        }
    }
    h
}

/// CDF of Student's t with `df` degrees of freedom.
pub fn student_t_cdf(t: f64, df: f64) -> f64 {
    let x = df / (df + t * t);
    let p = 0.5 * betainc(0.5 * df, 0.5, x);
    if t > 0.0 {
        1.0 - p
    } else {
        p
    }
}

/// Result of a Welch two-sample t-test.
#[derive(Clone, Copy, Debug)]
pub struct TTest {
    pub t: f64,
    pub df: f64,
    /// One-sided p-value for H1: mean(a) > mean(b).
    pub p_greater: f64,
    /// Two-sided p-value.
    pub p_two_sided: f64,
}

/// Welch's unequal-variance t-test of samples `a` vs `b`.
pub fn welch_t_test(a: &[f64], b: &[f64]) -> TTest {
    let (na, nb) = (a.len() as f64, b.len() as f64);
    let (ma, mb) = (mean(a), mean(b));
    let (va, vb) = (variance(a), variance(b));
    let se2 = va / na + vb / nb;
    let t = if se2 > 0.0 {
        (ma - mb) / se2.sqrt()
    } else if ma == mb {
        0.0
    } else if ma > mb {
        f64::INFINITY
    } else {
        f64::NEG_INFINITY
    };
    let df = if se2 > 0.0 {
        se2 * se2
            / ((va / na).powi(2) / (na - 1.0).max(1.0)
                + (vb / nb).powi(2) / (nb - 1.0).max(1.0))
    } else {
        na + nb - 2.0
    };
    let p_greater = if t.is_finite() {
        1.0 - student_t_cdf(t, df)
    } else if t > 0.0 {
        0.0
    } else {
        1.0
    };
    let p_two = if t.is_finite() {
        2.0 * (1.0 - student_t_cdf(t.abs(), df))
    } else {
        0.0
    };
    TTest {
        t,
        df,
        p_greater,
        p_two_sided: p_two,
    }
}

/// The paper's Table-1 decision: are the two result samples statistically
/// indistinguishable at level `alpha` (one-sided, either direction)?
pub fn not_significantly_different(a: &[f64], b: &[f64], alpha: f64) -> bool {
    let t = welch_t_test(a, b);
    t.p_greater > alpha && (1.0 - t.p_greater) > alpha
}

/// Pearson chi-square statistic of observed `counts` against expected
/// cell probabilities `probs` over `n` total draws. A draw landing in a
/// zero-probability cell returns infinity (an outright failure).
pub fn chi_square_stat(counts: &[usize], probs: &[f64], n: usize) -> f64 {
    debug_assert_eq!(counts.len(), probs.len());
    let mut chi2 = 0.0f64;
    for (&c, &p) in counts.iter().zip(probs) {
        let e = p * n as f64;
        if e > 0.0 {
            let d = c as f64 - e;
            chi2 += d * d / e;
        } else if c > 0 {
            return f64::INFINITY;
        }
    }
    chi2
}

/// Approximate upper critical value of the chi-square distribution with
/// `df` degrees of freedom at the one-sided normal quantile `z`, via the
/// Wilson–Hilferty cube transformation (z = 3.09 ⇒ alpha ≈ 1e-3). Good
/// to a few percent for df >= 3 — plenty for generous sampler tests.
pub fn chi_square_critical(df: f64, z: f64) -> f64 {
    let a = 2.0 / (9.0 * df);
    df * (1.0 - a + z * a.sqrt()).powi(3)
}

/// Kolmogorov–Smirnov distance between the empirical CDF of an ascending
/// `sorted` sample and a reference CDF (both one-sided deviations).
pub fn ks_distance(sorted: &[f64], cdf: impl Fn(f64) -> f64) -> f64 {
    let n = sorted.len() as f64;
    let mut dist = 0.0f64;
    for (i, &x) in sorted.iter().enumerate() {
        let f = cdf(x);
        dist = dist
            .max((f - i as f64 / n).abs())
            .max(((i + 1) as f64 / n - f).abs());
    }
    dist
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_var() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((variance(&xs) - 5.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn ln_gamma_known_values() {
        // Gamma(5) = 24
        assert!((ln_gamma(5.0) - 24f64.ln()).abs() < 1e-10);
        // Gamma(0.5) = sqrt(pi)
        assert!((ln_gamma(0.5) - 0.5 * std::f64::consts::PI.ln()).abs() < 1e-10);
    }

    #[test]
    fn betainc_symmetry_and_bounds() {
        assert_eq!(betainc(2.0, 3.0, 0.0), 0.0);
        assert_eq!(betainc(2.0, 3.0, 1.0), 1.0);
        let v = betainc(2.0, 2.0, 0.5);
        assert!((v - 0.5).abs() < 1e-10); // symmetric case
    }

    #[test]
    fn student_t_cdf_known_values() {
        // t=0 -> 0.5 for any df
        assert!((student_t_cdf(0.0, 5.0) - 0.5).abs() < 1e-12);
        // df=1 (Cauchy): CDF(1) = 0.75
        assert!((student_t_cdf(1.0, 1.0) - 0.75).abs() < 1e-9);
        // large df approaches normal: CDF(1.96, 1e6) ~ 0.975
        assert!((student_t_cdf(1.96, 1e6) - 0.975).abs() < 1e-3);
    }

    #[test]
    fn welch_detects_difference() {
        let a: Vec<f64> = (0..50).map(|i| 1.0 + (i % 5) as f64 * 0.01).collect();
        let b: Vec<f64> = (0..50).map(|i| 0.0 + (i % 5) as f64 * 0.01).collect();
        let t = welch_t_test(&a, &b);
        assert!(t.p_greater < 1e-6);
        assert!(!not_significantly_different(&a, &b, 0.05));
    }

    #[test]
    fn welch_accepts_same_distribution() {
        let a: Vec<f64> = (0..60).map(|i| ((i * 37) % 17) as f64).collect();
        let b: Vec<f64> = (0..60).map(|i| ((i * 23 + 5) % 17) as f64).collect();
        assert!(not_significantly_different(&a, &b, 0.05));
    }

    #[test]
    fn zero_variance_equal_means() {
        let a = [2.0, 2.0, 2.0];
        let b = [2.0, 2.0, 2.0];
        let t = welch_t_test(&a, &b);
        assert_eq!(t.t, 0.0);
    }

    #[test]
    fn chi_square_stat_zero_on_perfect_fit() {
        let probs = [0.25f64, 0.25, 0.5];
        let counts = [25usize, 25, 50];
        assert!(chi_square_stat(&counts, &probs, 100) < 1e-12);
        // a draw in a zero-probability cell is an outright failure
        let bad = chi_square_stat(&[1, 99, 0], &[0.0, 1.0, 0.0], 100);
        assert!(bad.is_infinite());
    }

    #[test]
    fn chi_square_critical_matches_tables() {
        // df=7, alpha=0.001 -> 24.32; df=15, alpha=0.001 -> 37.70
        let c7 = chi_square_critical(7.0, 3.0902);
        assert!((c7 - 24.32).abs() < 0.8, "df7 crit {c7}");
        let c15 = chi_square_critical(15.0, 3.0902);
        assert!((c15 - 37.70).abs() < 1.0, "df15 crit {c15}");
        // df=4, alpha=0.05 -> 9.488
        let c4 = chi_square_critical(4.0, 1.6449);
        assert!((c4 - 9.488).abs() < 0.3, "df4 crit {c4}");
    }

    #[test]
    fn ks_distance_detects_shift_and_accepts_exact() {
        // exact uniform grid against the uniform CDF: distance = 1/(2n)
        let n = 100;
        let sorted: Vec<f64> = (0..n).map(|i| (i as f64 + 0.5) / n as f64).collect();
        let d = ks_distance(&sorted, |x| x.clamp(0.0, 1.0));
        assert!(d <= 0.5 / n as f64 + 1e-12, "uniform grid distance {d}");
        // shifted sample is far from the uniform CDF
        let shifted: Vec<f64> = sorted.iter().map(|&x| 0.5 * x).collect();
        assert!(ks_distance(&shifted, |x| x.clamp(0.0, 1.0)) > 0.4);
    }
}
