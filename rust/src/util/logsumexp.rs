//! Numerically stable log-domain reductions.
//!
//! The sparse baseline engine (engine::sparse) leans on these per-node;
//! the dense engine implements the fused log-einsum-exp (Eq. 4) inline.

/// `log(sum_i exp(x_i))`, stable under large negative inputs.
/// Returns `-inf` for an empty slice or all `-inf` inputs.
pub fn logsumexp(xs: &[f32]) -> f32 {
    let m = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    if !m.is_finite() {
        return m;
    }
    let s: f32 = xs.iter().map(|&x| (x - m).exp()).sum();
    m + s.ln()
}

/// `log(sum_i w_i exp(x_i))` for linear-domain non-negative weights —
/// the scalar form of the paper's log-einsum-exp trick.
pub fn log_weighted_sum_exp(xs: &[f32], ws: &[f32]) -> f32 {
    debug_assert_eq!(xs.len(), ws.len());
    let m = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    if !m.is_finite() {
        return f32::NEG_INFINITY;
    }
    let s: f32 = xs
        .iter()
        .zip(ws)
        .map(|(&x, &w)| w * (x - m).exp())
        .sum();
    m + s.ln()
}

/// Two-value `log(exp(a) + exp(b))`.
#[inline]
pub fn logaddexp(a: f32, b: f32) -> f32 {
    if a == f32::NEG_INFINITY {
        return b;
    }
    if b == f32::NEG_INFINITY {
        return a;
    }
    let (hi, lo) = if a > b { (a, b) } else { (b, a) };
    hi + (lo - hi).exp().ln_1p()
}

/// f64 variant used by accumulation-sensitive statistics.
pub fn logsumexp_f64(xs: &[f64]) -> f64 {
    let m = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    if !m.is_finite() {
        return m;
    }
    let s: f64 = xs.iter().map(|&x| (x - m).exp()).sum();
    m + s.ln()
}

/// Streaming logsumexp over many values without materializing them:
/// maintains (max, scaled sum) and merges in O(1).
#[derive(Clone, Copy, Debug)]
pub struct StreamingLse {
    max: f64,
    sum: f64,
}

impl Default for StreamingLse {
    fn default() -> Self {
        Self {
            max: f64::NEG_INFINITY,
            sum: 0.0,
        }
    }
}

impl StreamingLse {
    pub fn push(&mut self, x: f64) {
        if x == f64::NEG_INFINITY {
            return;
        }
        if x <= self.max {
            self.sum += (x - self.max).exp();
        } else {
            self.sum = self.sum * (self.max - x).exp() + 1.0;
            self.max = x;
        }
    }

    pub fn value(&self) -> f64 {
        if self.max == f64::NEG_INFINITY {
            f64::NEG_INFINITY
        } else {
            self.max + self.sum.ln()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f32, b: f32, tol: f32) -> bool {
        (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
    }

    #[test]
    fn matches_naive_in_safe_range() {
        let xs = [0.5f32, -1.0, 2.0, 0.0];
        let naive = xs.iter().map(|x| x.exp()).sum::<f32>().ln();
        assert!(close(logsumexp(&xs), naive, 1e-6));
    }

    #[test]
    fn stable_under_large_negatives() {
        let xs = [-10_000.0f32, -10_001.0, -10_002.0];
        let v = logsumexp(&xs);
        assert!(v.is_finite());
        // exact: -10000 + ln(1 + e^-1 + e^-2)
        let want = -10_000.0 + (1.0 + (-1.0f32).exp() + (-2.0f32).exp()).ln();
        assert!((v - want).abs() < 1e-3);
    }

    #[test]
    fn empty_and_neg_inf() {
        assert_eq!(logsumexp(&[]), f32::NEG_INFINITY);
        assert_eq!(
            logsumexp(&[f32::NEG_INFINITY, f32::NEG_INFINITY]),
            f32::NEG_INFINITY
        );
    }

    #[test]
    fn weighted_matches_manual() {
        let xs = [-2.0f32, -3.0, -1.5];
        let ws = [0.2f32, 0.5, 0.3];
        let manual = xs
            .iter()
            .zip(&ws)
            .map(|(&x, &w)| w * x.exp())
            .sum::<f32>()
            .ln();
        assert!(close(log_weighted_sum_exp(&xs, &ws), manual, 1e-6));
    }

    #[test]
    fn weighted_stable_deep_log() {
        let xs = [-5000.0f32, -5001.0];
        let ws = [0.6f32, 0.4];
        assert!(log_weighted_sum_exp(&xs, &ws).is_finite());
    }

    #[test]
    fn logaddexp_symmetry_and_identity() {
        assert!(close(logaddexp(-1.0, -2.0), logaddexp(-2.0, -1.0), 1e-7));
        assert_eq!(logaddexp(f32::NEG_INFINITY, -3.0), -3.0);
        assert!(close(logaddexp(0.0, 0.0), 2.0f32.ln(), 1e-7));
    }

    #[test]
    fn streaming_matches_batch() {
        let xs: Vec<f64> = (0..100).map(|i| -(i as f64) * 13.7 % 29.0).collect();
        let mut s = StreamingLse::default();
        for &x in &xs {
            s.push(x);
        }
        assert!((s.value() - logsumexp_f64(&xs)).abs() < 1e-10);
    }
}
