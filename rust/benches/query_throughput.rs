//! Per-query-type throughput through the unified Query API.
//!
//! Measures `Engine::execute` over compiled plans at B = 256 on both
//! engines: fully-observed log-likelihood, half-observed marginal,
//! conditional (two passes), true max-product MPE (max-product forward +
//! backtrack) — including the raw MaxProduct-vs-SumProduct forward
//! comparison — plus conditional inpainting and unconditional sampling.
//! Results go to stdout and BENCH_queries.json.
//!
//!     cargo bench --bench query_throughput
//!     EINET_BENCH_QUICK=1 cargo bench --bench query_throughput

use einet::bench::{fmt_si, time_it, Table};
use einet::util::json;
use einet::util::rng::Rng;
use einet::{
    DecodeMode, DenseEngine, EinetParams, Engine, LayeredPlan, LeafFamily, Query,
    QueryOutput, Semiring, SparseEngine,
};

struct Row {
    engine: &'static str,
    loglik_s: f64,
    marginal_s: f64,
    conditional_s: f64,
    mpe_s: f64,
    fwd_sum_s: f64,
    fwd_max_s: f64,
    inpaint_s: f64,
    sample_s: f64,
}

fn bench_engine<E: Engine>(
    name: &'static str,
    plan: &LayeredPlan,
    batch: usize,
    repeats: usize,
) -> Row {
    let family = LeafFamily::Bernoulli;
    let params = EinetParams::init(plan, family, 0);
    let mut engine = E::build(plan.clone(), family, batch);
    let nv = plan.graph.num_vars;

    let mut rng = Rng::new(1);
    let x: Vec<f32> = (0..batch * nv)
        .map(|_| if rng.bernoulli(0.5) { 1.0 } else { 0.0 })
        .collect();
    let emask: Vec<f32> = (0..nv).map(|d| if d % 2 == 0 { 1.0 } else { 0.0 }).collect();
    let qmask: Vec<f32> = (0..nv)
        .map(|d| if d % 2 == 1 && d < nv / 2 { 1.0 } else { 0.0 })
        .collect();

    let mut out = QueryOutput::default();
    let mut run = |query: Query, rng: &mut Rng, out: &mut QueryOutput| -> f64 {
        let qp = query.compile(nv).unwrap();
        time_it(
            || {
                engine.execute(&params, &qp, &x, batch, rng, out);
                std::hint::black_box(out.scores.len().max(out.rows.len()));
            },
            1,
            repeats,
        )
        .median_s
    };

    let loglik_s = run(Query::LogLik, &mut rng, &mut out);
    let marginal_s = run(Query::Marginal { mask: emask.clone() }, &mut rng, &mut out);
    let conditional_s = run(
        Query::Conditional {
            query_mask: qmask,
            evidence_mask: emask.clone(),
        },
        &mut rng,
        &mut out,
    );
    let mpe_s = run(Query::Mpe { mask: emask.clone() }, &mut rng, &mut out);
    let inpaint_s = run(
        Query::Inpaint {
            mask: emask.clone(),
            mode: DecodeMode::Sample,
        },
        &mut rng,
        &mut out,
    );
    let sample_s = run(Query::Sample { n: batch }, &mut rng, &mut out);

    // raw forward comparison: the same mask under both semirings
    let mut logp = vec![0.0f32; batch];
    let fwd_sum_s = time_it(
        || {
            engine.forward_semiring(&params, &x, &emask, &mut logp, Semiring::SumProduct);
            std::hint::black_box(logp[0]);
        },
        1,
        repeats,
    )
    .median_s;
    let fwd_max_s = time_it(
        || {
            engine.forward_semiring(&params, &x, &emask, &mut logp, Semiring::MaxProduct);
            std::hint::black_box(logp[0]);
        },
        1,
        repeats,
    )
    .median_s;

    Row {
        engine: name,
        loglik_s,
        marginal_s,
        conditional_s,
        mpe_s,
        fwd_sum_s,
        fwd_max_s,
        inpaint_s,
        sample_s,
    }
}

fn main() {
    let quick = std::env::var("EINET_BENCH_QUICK").is_ok();
    let batch = 256usize;
    let repeats = if quick { 3 } else { 7 };
    let (nv, k, depth, rep) = if quick { (64, 8, 4, 4) } else { (128, 10, 5, 6) };

    let plan = LayeredPlan::compile(
        einet::structure::random_binary_trees(nv, depth, rep, 7),
        k,
    );

    println!("Query throughput — unified Engine::execute, B={batch}, D={nv}, K={k}");
    let rows = vec![
        bench_engine::<DenseEngine>("dense", &plan, batch, repeats),
        bench_engine::<SparseEngine>("sparse", &plan, batch, repeats),
    ];

    let mut table = Table::new(&[
        "engine", "loglik", "marginal", "conditional", "mpe", "fwd max/sum",
        "inpaint", "sample",
    ]);
    let mut report_rows: Vec<json::Json> = Vec::new();
    for r in &rows {
        let max_over_sum = r.fwd_max_s / r.fwd_sum_s;
        table.row(vec![
            r.engine.to_string(),
            fmt_si(r.loglik_s),
            fmt_si(r.marginal_s),
            fmt_si(r.conditional_s),
            fmt_si(r.mpe_s),
            format!("{max_over_sum:.2}x"),
            fmt_si(r.inpaint_s),
            fmt_si(r.sample_s),
        ]);
        println!(
            "{:<7} loglik {}  marginal {}  cond {}  mpe {}  inpaint {}  sample {}",
            r.engine,
            fmt_si(r.loglik_s),
            fmt_si(r.marginal_s),
            fmt_si(r.conditional_s),
            fmt_si(r.mpe_s),
            fmt_si(r.inpaint_s),
            fmt_si(r.sample_s),
        );
        let qps = |s: f64| batch as f64 / s;
        report_rows.push(json::obj(vec![
            ("engine", json::s(r.engine)),
            ("batch", json::num(batch as f64)),
            ("loglik_rows_per_s", json::num(qps(r.loglik_s))),
            ("marginal_rows_per_s", json::num(qps(r.marginal_s))),
            ("conditional_rows_per_s", json::num(qps(r.conditional_s))),
            ("mpe_rows_per_s", json::num(qps(r.mpe_s))),
            ("inpaint_rows_per_s", json::num(qps(r.inpaint_s))),
            ("sample_rows_per_s", json::num(qps(r.sample_s))),
            ("forward_sum_product_s", json::num(r.fwd_sum_s)),
            ("forward_max_product_s", json::num(r.fwd_max_s)),
            ("max_over_sum_forward_ratio", json::num(r.fwd_max_s / r.fwd_sum_s)),
        ]));
    }
    println!("\n{}", table.render());
    let report = json::obj(vec![
        ("experiment", json::s("query_throughput")),
        ("quick", json::num(quick as i32 as f64)),
        ("batch", json::num(batch as f64)),
        ("num_vars", json::num(nv as f64)),
        ("k", json::num(k as f64)),
        ("rows", json::arr(report_rows)),
    ]);
    std::fs::write("BENCH_queries.json", report.to_string())
        .expect("write BENCH_queries.json");
    println!("wrote BENCH_queries.json");
}
