//! Scope-partitioned execution scaling: stochastic-EM training and
//! forward serving throughput of the [`ShardedPool`] at 1 / 2 / 4 shards,
//! dense and sparse engines, on the Fig. 3-size model (RAT, D=512,
//! depth 4, replica 10, K=10, Gaussian leaves; quick mode scales the
//! model down but keeps the shape).
//!
//! The 1-shard pool is the baseline — identical machinery, one worker —
//! so the reported speedups isolate the scope-partitioning itself
//! (N-shard results are bit-identical to 1-shard, see
//! `tests/sharding_parity.rs`; this bench measures only throughput).
//! Results land in BENCH_sharding.json (CI artifact).
//!
//!     cargo bench --bench sharding_scaling            # full size
//!     EINET_BENCH_QUICK=1 cargo bench --bench sharding_scaling

use einet::bench::{fmt_si, time_it, Table};
use einet::coordinator::ShardedPool;
use einet::data::debd::gaussian_noise;
use einet::em::EmConfig;
use einet::util::json;
use einet::{
    boxed_build, DenseEngine, EinetParams, EngineFactory, LayeredPlan, LeafFamily,
    SparseEngine,
};

struct PathResult {
    train_samples_per_s: f64,
    serve_samples_per_s: f64,
}

#[allow(clippy::too_many_arguments)]
fn run_point(
    factory: EngineFactory,
    plan: &LayeredPlan,
    family: LeafFamily,
    params0: &EinetParams,
    data: &[f32],
    n: usize,
    batch: usize,
    shards: usize,
    reps: usize,
) -> PathResult {
    let d = plan.graph.num_vars;
    let mask = vec![1.0f32; d];
    let em = EmConfig {
        step_size: 0.5,
        var_bounds: (1e-3, 10.0),
        ..Default::default()
    };
    let mut pool = ShardedPool::new(factory, plan, family, params0, shards, batch);

    // zero-copy hand-off: the dataset and mask are wrapped in Arcs once,
    // each batch ships as a pointer + row range
    let data = std::sync::Arc::new(data.to_vec());
    let mask = std::sync::Arc::new(mask);

    // --- train: one epoch of sharded stochastic EM per rep -------------
    let mut run_train = || {
        pool.set_params(params0).unwrap();
        let mut b0 = 0usize;
        while b0 < n {
            let bn = batch.min(n - b0);
            pool.train_step_shared(data.clone(), b0, mask.clone(), bn, &em)
                .unwrap();
            b0 += bn;
        }
    };
    run_train(); // warmup
    let mt = time_it(&mut run_train, 0, reps);

    // --- serve: forward-only batched log-likelihood queries ------------
    let mut logp = vec![0.0f32; batch];
    let mut run_serve = || {
        let mut b0 = 0usize;
        while b0 < n {
            let bn = batch.min(n - b0);
            pool.forward_shared(
                data.clone(),
                b0,
                mask.clone(),
                bn,
                einet::Semiring::SumProduct,
                &mut logp[..bn],
            )
            .unwrap();
            b0 += bn;
        }
    };
    run_serve(); // warmup
    let ms = time_it(&mut run_serve, 0, reps);

    PathResult {
        train_samples_per_s: n as f64 / mt.median_s,
        serve_samples_per_s: n as f64 / ms.median_s,
    }
}

fn main() {
    let quick = std::env::var("EINET_BENCH_QUICK").is_ok();
    let (num_vars, depth, replica, k) =
        if quick { (128, 3, 8, 6) } else { (512, 4, 10, 10) };
    let n = if quick { 100 } else { 300 };
    let batch = 50usize;
    let reps = if quick { 2 } else { 3 };
    let family = LeafFamily::Gaussian { channels: 1 };
    let data = gaussian_noise(n, num_vars, 0);

    let graph = einet::structure::random_binary_trees(num_vars, depth, replica, 7);
    let plan = LayeredPlan::compile(graph, k);
    let params0 = EinetParams::init(&plan, family, 0);

    println!(
        "sharding scaling — RAT D={num_vars} depth={depth} R={replica} K={k}, \
         N={n}, batch={batch} ({} params)",
        params0.num_params()
    );
    let mut table = Table::new(&[
        "engine", "shards", "train t/epoch", "train samples/s", "serve samples/s",
    ]);
    let engines: [(&str, EngineFactory); 2] = [
        ("dense", boxed_build::<DenseEngine>),
        ("sparse", boxed_build::<SparseEngine>),
    ];
    let shard_counts = [1usize, 2, 4];
    let mut rows: Vec<json::Json> = Vec::new();
    let mut speedup_4x = Vec::new();
    for (name, factory) in engines {
        let mut base_train = 0.0f64;
        for &shards in &shard_counts {
            let r = run_point(
                factory, &plan, family, &params0, &data.data, n, batch, shards, reps,
            );
            if shards == 1 {
                base_train = r.train_samples_per_s;
            }
            table.row(vec![
                name.to_string(),
                format!("{shards}"),
                fmt_si(n as f64 / r.train_samples_per_s),
                format!("{:.0}", r.train_samples_per_s),
                format!("{:.0}", r.serve_samples_per_s),
            ]);
            println!(
                "{name} x{shards}: train {:.0} samples/s, serve {:.0} samples/s",
                r.train_samples_per_s, r.serve_samples_per_s
            );
            if shards == 4 {
                let s = r.train_samples_per_s / base_train;
                println!("{name}: 4-shard train speedup {s:.2}x over 1-shard");
                speedup_4x.push((name, s));
            }
            rows.push(json::obj(vec![
                ("engine", json::s(name)),
                ("shards", json::num(shards as f64)),
                ("train_samples_per_s", json::num(r.train_samples_per_s)),
                ("serve_samples_per_s", json::num(r.serve_samples_per_s)),
            ]));
        }
    }
    println!("\n{}", table.render());

    let mut summary = vec![
        ("experiment", json::s("sharding_scaling")),
        ("quick", json::num(quick as i32 as f64)),
        ("num_vars", json::num(num_vars as f64)),
        ("depth", json::num(depth as f64)),
        ("replica", json::num(replica as f64)),
        ("k", json::num(k as f64)),
        ("n", json::num(n as f64)),
        ("batch", json::num(batch as f64)),
        ("rows", json::arr(rows)),
    ];
    for (name, s) in &speedup_4x {
        summary.push(match *name {
            "dense" => ("train_speedup_4x_dense", json::num(*s)),
            _ => ("train_speedup_4x_sparse", json::num(*s)),
        });
    }
    let report = json::obj(summary);
    std::fs::write("BENCH_sharding.json", report.to_string())
        .expect("write BENCH_sharding.json");
    println!("wrote BENCH_sharding.json");
}
