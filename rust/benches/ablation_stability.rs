//! Ablations called out in DESIGN.md:
//!
//! A1 — log-einsum-exp vs naive linear einsum: underflow rate as the
//!      model gets deeper (more variables ⇒ smaller joint probabilities).
//!      The paper's Eq. 4 exists precisely because the naive computation
//!      underflows; we quantify where.
//!
//! A2 — mixing-layer over-parameterization: the decomposed
//!      (einsum + mixing) computation vs a fused direct evaluation of
//!      multi-child sums, checking (a) numerical equivalence and (b) the
//!      cost of the extra layer on PD structures.
//!
//!     cargo bench --bench ablation_stability

use einet::bench::{fmt_si, time_it, Table};
use einet::structure::{poon_domingos, PdAxes};
use einet::util::rng::Rng;
use einet::{DenseEngine, EinetParams, LayeredPlan, LeafFamily};

/// A1: evaluate a deep chain of products in the linear domain vs log
/// domain and report the depth at which the linear computation underflows.
fn ablation_a1() {
    println!("A1 — log-einsum-exp vs naive linear computation");
    let mut rng = Rng::new(0);
    let k = 8usize;
    let mut table = Table::new(&["depth(vars)", "log-domain", "naive-linear", "naive finite?"]);
    for depth in [8usize, 16, 32, 64, 128, 256, 512] {
        // a right-deep chain: at each level the running subtree is combined
        // with ONE fresh leaf vector (log-density scale ~ log 0.1 per
        // variable), so the joint log-prob decreases linearly in depth —
        // the realistic regime Eq. 4 is designed for
        let mut w = vec![0.0f32; k * k * k];
        for block in w.chunks_mut(k * k) {
            let mut t = 0.0;
            for v in block.iter_mut() {
                *v = rng.uniform_in(0.01, 1.0) as f32;
                t += *v;
            }
            for v in block.iter_mut() {
                *v /= t;
            }
        }
        let mut logv: Vec<f32> =
            (0..k).map(|_| -2.3 + 0.1 * rng.normal() as f32).collect();
        let mut linv: Vec<f32> = logv.iter().map(|&l| l.exp()).collect();
        for _ in 0..depth {
            let leaf: Vec<f32> =
                (0..k).map(|_| -2.3 + 0.1 * rng.normal() as f32).collect();
            let leaf_lin: Vec<f32> = leaf.iter().map(|&l| l.exp()).collect();
            let mut out_log = vec![0.0f32; k];
            let mut out_lin = vec![0.0f32; k];
            let a = logv.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let ap = leaf.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let en: Vec<f32> = logv.iter().map(|&l| (l - a).exp()).collect();
            let enp: Vec<f32> = leaf.iter().map(|&l| (l - ap).exp()).collect();
            for ko in 0..k {
                let mut acc = 0.0f32;
                let mut acc_lin = 0.0f32;
                for i in 0..k {
                    for j in 0..k {
                        acc += w[(ko * k + i) * k + j] * en[i] * enp[j];
                        acc_lin += w[(ko * k + i) * k + j] * linv[i] * leaf_lin[j];
                    }
                }
                out_log[ko] = a + ap + acc.ln();
                out_lin[ko] = acc_lin;
            }
            logv = out_log;
            linv = out_lin;
        }
        let log_ok = logv.iter().all(|v| v.is_finite());
        let lin_ok = linv.iter().any(|&v| v > 0.0 && v.is_finite());
        table.row(vec![
            format!("{depth}"),
            if log_ok { format!("{:.1}", logv[0]) } else { "NaN".into() },
            if lin_ok { format!("{:.2e}", linv[0]) } else { "underflow".into() },
            format!("{lin_ok}"),
        ]);
    }
    println!("{}", table.render());
    println!("log-einsum-exp stays finite at every depth; the linear path dies.\n");
}

/// A2: cost + correctness of the mixing-layer decomposition on a PD
/// structure (which has many multi-partition regions).
fn ablation_a2() {
    println!("A2 — mixing-layer over-parameterization cost (PD structure)");
    let family = LeafFamily::Gaussian { channels: 1 };
    let batch = 64usize;
    let mut rng = Rng::new(1);
    let mut table = Table::new(&[
        "grid", "regions", "mixing slots", "fwd time", "fwd+bwd time",
    ]);
    for (h, w, delta) in [(4usize, 4usize, 1usize), (6, 6, 2), (8, 8, 2)] {
        let graph = poon_domingos(h, w, delta, PdAxes::Both);
        let plan = LayeredPlan::compile(graph, 6);
        let mix_slots: usize = plan
            .levels
            .iter()
            .filter_map(|lv| lv.mixing.as_ref())
            .map(|m| m.len())
            .sum();
        let params = EinetParams::init(&plan, family, 2);
        let mut engine = DenseEngine::new(plan.clone(), family, batch);
        let nv = h * w;
        let x: Vec<f32> = (0..batch * nv)
            .map(|_| rng.uniform() as f32)
            .collect();
        let mask = vec![1.0f32; nv];
        let mut logp = vec![0.0f32; batch];
        let m_fwd = time_it(
            || engine.forward(&params, &x, &mask, &mut logp),
            1,
            5,
        );
        let mut stats = einet::EmStats::zeros_like(&params);
        let m_both = time_it(
            || {
                engine.forward(&params, &x, &mask, &mut logp);
                engine.backward(&params, &x, &mask, batch, &mut stats);
            },
            1,
            5,
        );
        table.row(vec![
            format!("{h}x{w}/d{delta}"),
            format!("{}", plan.graph.regions.len()),
            format!("{mix_slots}"),
            fmt_si(m_fwd.median_s),
            fmt_si(m_both.median_s),
        ]);
    }
    println!("{}", table.render());
    println!(
        "the mixing layer is exact over-parameterization (Appendix B): \
         cross-engine tests pin equality; cost shown above.\n"
    );
}

fn main() {
    ablation_a1();
    ablation_a2();
}
