//! End-to-end dataset harness: bits-per-dim on DEBD-format fixtures and
//! classify accuracy on the committed class-conditional image fixture.
//!
//! Everything runs offline from `fixtures/` (tiny committed files in the
//! real on-disk formats — see `fixtures/gen_fixtures.py` for
//! provenance), through the *file* loaders (`data::debd::load_dir`,
//! `data::images::load_labeled`) with their load-time family validation,
//! so the numbers are comparable across commits and CI needs no network.
//! Per dataset we train with batch EM and with an online-EM decay
//! policy, and report test-set bits-per-dim `-LL / (D ln 2)` for both;
//! the labeled fixture trains a class-conditional circuit
//! (`LayeredPlan::with_classes`) and reports classify accuracy, which CI
//! asserts >= 0.9.
//!
//!     EINET_BENCH_QUICK=1 cargo bench --bench dataset_bpd

use std::path::Path;

use einet::bench::Table;
use einet::coordinator::{
    classify_accuracy, evaluate, train_class_conditional, train_parallel, TrainConfig,
};
use einet::data::{debd, images};
use einet::em::{StepSchedule, UpdatePolicy};
use einet::util::json;
use einet::{DenseEngine, EinetParams, LayeredPlan, LeafFamily};

const LN2: f64 = std::f64::consts::LN_2;

fn main() {
    let quick = std::env::var("EINET_BENCH_QUICK").is_ok();
    let fixtures = Path::new(env!("CARGO_MANIFEST_DIR")).join("fixtures");
    let family = LeafFamily::Bernoulli;
    let epochs = if quick { 3 } else { 12 };

    let mut table = Table::new(&["dataset", "D", "train n", "bpd (batch)", "bpd (online)"]);
    let mut rows: Vec<json::Json> = Vec::new();
    for name in ["nltcs", "msnbc"] {
        let ds = debd::load_dir(&fixtures.join("debd"), name).expect("load DEBD fixture");
        ds.validate_family(family).expect("fixture arity vs leaf family");
        let graph = einet::structure::random_binary_trees(ds.num_vars, 2, 4, 0);
        let plan = LayeredPlan::compile(graph, 4);
        let mut bpd = [0.0f64; 2];
        for (slot, policy) in [
            (0usize, UpdatePolicy::full_batch()),
            (
                1usize,
                UpdatePolicy {
                    frequency: 1,
                    schedule: StepSchedule::Decay { s0: 0.8, alpha: 0.7 },
                },
            ),
        ] {
            let mut params = EinetParams::init(&plan, family, 7);
            let cfg = TrainConfig {
                epochs,
                batch_size: 64,
                workers: 2,
                policy,
                log_every: 0,
                ..Default::default()
            };
            train_parallel::<DenseEngine>(
                &plan, family, &mut params, &ds.train.data, ds.train.n, &cfg,
            );
            let test_ll = evaluate::<DenseEngine>(
                &plan, family, &params, &ds.test.data, ds.test.n, 64,
            );
            bpd[slot] = -test_ll / (ds.num_vars as f64 * LN2);
        }
        println!(
            "{name} bpd batch {:.4} online {:.4}",
            bpd[0], bpd[1]
        );
        table.row(vec![
            name.to_string(),
            format!("{}", ds.num_vars),
            format!("{}", ds.train.n),
            format!("{:.4}", bpd[0]),
            format!("{:.4}", bpd[1]),
        ]);
        rows.push(json::obj(vec![
            ("dataset", json::s(name)),
            ("num_vars", json::num(ds.num_vars as f64)),
            ("train_n", json::num(ds.train.n as f64)),
            ("bpd_batch", json::num(bpd[0])),
            ("bpd_online", json::num(bpd[1])),
        ]));
    }

    // class-conditional fixture: train p(x | c) with one root per class,
    // report argmax-posterior accuracy through Query::Classify
    let li = images::load_labeled(&fixtures.join("images/digits3.eimg"))
        .expect("load labeled image fixture");
    li.split
        .validate_family(family, "digits3")
        .expect("fixture arity vs leaf family");
    let d = li.split.row_len;
    let graph = einet::structure::random_binary_trees(d, 2, 4, 1);
    let plan = LayeredPlan::compile(graph, 4)
        .with_classes(li.classes)
        .expect("widen root");
    let mut params = EinetParams::init(&plan, family, 11);
    let cfg = TrainConfig {
        epochs: if quick { 4 } else { 12 },
        batch_size: 60,
        workers: 1,
        log_every: 0,
        ..Default::default()
    };
    train_class_conditional::<DenseEngine>(
        &plan,
        family,
        &mut params,
        &li.split.data,
        &li.labels,
        li.split.n,
        &cfg,
    );
    let acc = classify_accuracy::<DenseEngine>(
        &plan,
        family,
        &params,
        &li.split.data,
        &li.labels,
        li.split.n,
        64,
    )
    .expect("classify");
    println!(
        "classify accuracy {:.4} on digits3 ({} images, {} classes)",
        acc, li.split.n, li.classes
    );
    println!("\n{}", table.render());

    let report = json::obj(vec![
        ("experiment", json::s("dataset_bpd")),
        ("quick", json::num(quick as i32 as f64)),
        ("epochs", json::num(epochs as f64)),
        ("rows", json::arr(rows)),
        (
            "classify",
            json::obj(vec![
                ("fixture", json::s("digits3")),
                ("n", json::num(li.split.n as f64)),
                ("classes", json::num(li.classes as f64)),
                ("accuracy", json::num(acc)),
            ]),
        ),
    ]);
    std::fs::write("BENCH_datasets.json", report.to_string())
        .expect("write BENCH_datasets.json");
    println!("wrote BENCH_datasets.json");
}
