//! Fig. 3 reproduction: training time per epoch + peak memory of the
//! EiNet (dense einsum) layout vs the LibSPN/SPFlow-style sparse layout,
//! sweeping the three structural hyper-parameters of RAT structures:
//!
//!   K (densities per sum/leaf), depth D, replica R
//!
//! Paper setup: Gaussian-noise data, N = 2000 samples, D = 512 dims,
//! single-dimensional Gaussian leaves, defaults (D=4, R=10, K=10); we
//! scale N down (CPU, not a 2080 Ti) but keep the sweep shape. The claim
//! under test: the dense layout is 1-2 orders of magnitude faster and
//! substantially smaller at large K/D/R, growing gracefully.
//!
//!     cargo bench --bench fig3_train            # full sweep
//!     EINET_BENCH_QUICK=1 cargo bench --bench fig3_train

use einet::bench::{fmt_bytes, fmt_si, time_it, Table};
use einet::data::debd::gaussian_noise;
use einet::em::{m_step, EmConfig};
use einet::{
    DenseEngine, EinetParams, EmStats, LayeredPlan, LeafFamily, SparseEngine,
};

struct SweepPoint {
    label: String,
    k: usize,
    depth: usize,
    replica: usize,
}

fn sweep() -> Vec<SweepPoint> {
    let quick = std::env::var("EINET_BENCH_QUICK").is_ok();
    let mut pts = Vec::new();
    let kk: &[usize] = if quick { &[2, 8] } else { &[1, 2, 4, 8, 16, 32] };
    let dd: &[usize] = if quick { &[2, 4] } else { &[1, 2, 3, 4, 5, 6] };
    let rr: &[usize] = if quick { &[2, 8] } else { &[1, 2, 5, 10, 20] };
    for &k in kk {
        pts.push(SweepPoint { label: format!("K={k}"), k, depth: 4, replica: 10 });
    }
    for &d in dd {
        pts.push(SweepPoint { label: format!("D={d}"), k: 10, depth: d, replica: 10 });
    }
    for &r in rr {
        pts.push(SweepPoint { label: format!("R={r}"), k: 10, depth: 4, replica: r });
    }
    pts
}

fn main() {
    let quick = std::env::var("EINET_BENCH_QUICK").is_ok();
    let num_vars = if quick { 128 } else { 512 };
    let n = if quick { 200 } else { 500 };
    let batch = 100usize;
    let data = gaussian_noise(n, num_vars, 0);
    let family = LeafFamily::Gaussian { channels: 1 };
    // unit-variance data: the paper's image-oriented variance clamp would
    // degenerate the leaves (and let exp-underflow skip work in later
    // epochs, biasing the timing) — use bounds that fit the data scale
    let em = EmConfig {
        var_bounds: (1e-3, 10.0),
        ..Default::default()
    };
    let mask = vec![1.0f32; num_vars];

    println!(
        "Fig. 3 — train time/epoch + memory, Gaussian noise N={n} D={num_vars}, batch={batch}"
    );
    let mut table = Table::new(&[
        "point", "params", "dense t/epoch", "sparse t/epoch", "speedup",
        "dense mem", "sparse mem", "mem ratio",
    ]);

    for pt in sweep() {
        let graph = einet::structure::random_binary_trees(
            num_vars, pt.depth, pt.replica, 7,
        );
        let plan = LayeredPlan::compile(graph, pt.k);
        let params = EinetParams::init(&plan, family, 0);

        // ---- dense (EiNet) --------------------------------------------
        // every timed epoch starts from the same fresh parameters so all
        // repetitions (and both engines) do identical numerical work
        let mut dense = DenseEngine::new(plan.clone(), family, batch);
        let mut p_dense = params.clone();
        let mut run_dense = || {
            p_dense.clone_from(&params);
            let mut stats = EmStats::zeros_like(&p_dense);
            let mut logp = vec![0.0f32; batch];
            let mut b0 = 0;
            while b0 < n {
                let bn = batch.min(n - b0);
                let xs = data.rows(b0, b0 + bn);
                dense.forward(&p_dense, xs, &mask, &mut logp[..bn]);
                dense.backward(&p_dense, xs, &mask, bn, &mut stats);
                m_step(&mut p_dense, &plan, &stats, &em);
                stats.reset();
                b0 += bn;
            }
        };
        run_dense(); // warmup + establish timing scale
        let md = time_it(run_dense, 0, if quick { 2 } else { 3 });

        // ---- sparse (LibSPN/SPFlow-style) ------------------------------
        let mut sparse = SparseEngine::new(plan.clone(), family, batch);
        let mut p_sparse = params.clone();
        let mut run_sparse = || {
            p_sparse.clone_from(&params);
            let mut stats = EmStats::zeros_like(&p_sparse);
            let mut logp = vec![0.0f32; batch];
            let mut b0 = 0;
            while b0 < n {
                let bn = batch.min(n - b0);
                let xs = data.rows(b0, b0 + bn);
                sparse.forward(&p_sparse, xs, &mask, &mut logp[..bn]);
                sparse.backward(&p_sparse, xs, &mask, bn, &mut stats);
                m_step(&mut p_sparse, &plan, &stats, &em);
                stats.reset();
                b0 += bn;
            }
        };
        run_sparse();
        let ms = time_it(run_sparse, 0, if quick { 2 } else { 3 });

        let mem_d = dense.memory_footprint(&params).total();
        let mem_s = sparse.memory_footprint(&params).total();
        table.row(vec![
            pt.label.clone(),
            format!("{}", params.num_params()),
            fmt_si(md.median_s),
            fmt_si(ms.median_s),
            format!("{:.1}x", ms.median_s / md.median_s),
            fmt_bytes(mem_d),
            fmt_bytes(mem_s),
            format!("{:.1}x", mem_s as f64 / mem_d as f64),
        ]);
        println!(
            "{:<6} dense {} sparse {} speedup {:.1}x  mem {} vs {}",
            pt.label,
            fmt_si(md.median_s),
            fmt_si(ms.median_s),
            ms.median_s / md.median_s,
            fmt_bytes(mem_d),
            fmt_bytes(mem_s)
        );
    }
    println!("\n{}", table.render());
}
