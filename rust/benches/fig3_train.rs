//! Fig. 3 reproduction: training time per epoch + peak memory of the
//! EiNet (dense einsum) layout vs the LibSPN/SPFlow-style sparse layout,
//! sweeping the three structural hyper-parameters of RAT structures:
//!
//!   K (densities per sum/leaf), depth D, replica R
//!
//! Paper setup: Gaussian-noise data, N = 2000 samples, D = 512 dims,
//! single-dimensional Gaussian leaves, defaults (D=4, R=10, K=10); we
//! scale N down (CPU, not a 2080 Ti) but keep the sweep shape. The claim
//! under test: the dense layout is 1-2 orders of magnitude faster and
//! substantially smaller at large K/D/R, growing gracefully.
//!
//! Also measures the coordinator's persistent worker pool against the old
//! per-mini-batch `thread::scope` spawning design on the Fig. 3 default
//! config, and records everything (including dense forward throughput,
//! for before/after regression tracking) in BENCH_fig3.json.
//!
//!     cargo bench --bench fig3_train            # full sweep
//!     EINET_BENCH_QUICK=1 cargo bench --bench fig3_train

use std::sync::mpsc;

use einet::bench::{fmt_bytes, fmt_si, time_it, Table};
use einet::coordinator::{train_parallel, TrainConfig};
use einet::data::debd::gaussian_noise;
use einet::em::{m_step, EmConfig};
use einet::util::json;
use einet::{
    DenseEngine, EinetParams, EmStats, LayeredPlan, LeafFamily, SparseEngine,
};

struct SweepPoint {
    label: String,
    k: usize,
    depth: usize,
    replica: usize,
}

fn sweep() -> Vec<SweepPoint> {
    let quick = std::env::var("EINET_BENCH_QUICK").is_ok();
    let mut pts = Vec::new();
    let kk: &[usize] = if quick { &[2, 8] } else { &[1, 2, 4, 8, 16, 32] };
    let dd: &[usize] = if quick { &[2, 4] } else { &[1, 2, 3, 4, 5, 6] };
    let rr: &[usize] = if quick { &[2, 8] } else { &[1, 2, 5, 10, 20] };
    for &k in kk {
        pts.push(SweepPoint { label: format!("K={k}"), k, depth: 4, replica: 10 });
    }
    for &d in dd {
        pts.push(SweepPoint { label: format!("D={d}"), k: 10, depth: d, replica: 10 });
    }
    for &r in rr {
        pts.push(SweepPoint { label: format!("R={r}"), k: 10, depth: 4, replica: r });
    }
    pts
}

/// The coordinator's PREVIOUS design, kept here as the baseline for the
/// worker-pool comparison: engines are reused, but a `thread::scope` is
/// opened (and its threads spawned and joined) for EVERY mini-batch.
#[allow(clippy::too_many_arguments)]
fn train_epoch_spawn_per_batch(
    plan: &LayeredPlan,
    family: LeafFamily,
    params: &mut EinetParams,
    engines: &mut [DenseEngine],
    data: &[f32],
    n: usize,
    batch: usize,
    em: &EmConfig,
) {
    let d = plan.graph.num_vars;
    let od = family.obs_dim();
    let row = d * od;
    let workers = engines.len();
    let mask = vec![1.0f32; d];
    let mut b0 = 0usize;
    while b0 < n {
        let bn = batch.min(n - b0);
        let batch_data = &data[b0 * row..(b0 + bn) * row];
        let shard = bn.div_ceil(workers);
        let mut merged = EmStats::zeros_like(params);
        std::thread::scope(|scope| {
            let (tx, rx) = mpsc::channel::<EmStats>();
            for (w, engine) in engines.iter_mut().enumerate() {
                let lo = (w * shard).min(bn);
                let hi = ((w + 1) * shard).min(bn);
                if lo >= hi {
                    continue;
                }
                let tx = tx.clone();
                let mask = &mask;
                let params = &*params;
                let chunk = &batch_data[lo * row..hi * row];
                scope.spawn(move || {
                    let bn_w = hi - lo;
                    let mut stats = EmStats::zeros_like(params);
                    let mut logp = vec![0.0f32; bn_w];
                    engine.forward(params, chunk, mask, &mut logp);
                    engine.backward(params, chunk, mask, bn_w, &mut stats);
                    let _ = tx.send(stats);
                });
            }
            drop(tx);
            while let Ok(stats) = rx.recv() {
                merged.merge(&stats);
            }
        });
        m_step(params, &merged, em);
        b0 += bn;
    }
}

fn main() {
    let quick = std::env::var("EINET_BENCH_QUICK").is_ok();
    let num_vars = if quick { 128 } else { 512 };
    let n = if quick { 200 } else { 500 };
    let batch = 100usize;
    let data = gaussian_noise(n, num_vars, 0);
    let family = LeafFamily::Gaussian { channels: 1 };
    // unit-variance data: the paper's image-oriented variance clamp would
    // degenerate the leaves (and let exp-underflow skip work in later
    // epochs, biasing the timing) — use bounds that fit the data scale
    let em = EmConfig {
        var_bounds: (1e-3, 10.0),
        ..Default::default()
    };
    let mask = vec![1.0f32; num_vars];
    let mut report_rows: Vec<json::Json> = Vec::new();

    println!(
        "Fig. 3 — train time/epoch + memory, Gaussian noise N={n} D={num_vars}, batch={batch}"
    );
    let mut table = Table::new(&[
        "point", "params", "dense t/epoch", "sparse t/epoch", "speedup",
        "dense mem", "sparse mem", "mem ratio",
    ]);

    for pt in sweep() {
        let graph = einet::structure::random_binary_trees(
            num_vars, pt.depth, pt.replica, 7,
        );
        let plan = LayeredPlan::compile(graph, pt.k);
        let params = EinetParams::init(&plan, family, 0);

        // ---- dense (EiNet) --------------------------------------------
        // every timed epoch starts from the same fresh parameters so all
        // repetitions (and both engines) do identical numerical work
        let mut dense = DenseEngine::new(plan.clone(), family, batch);
        let mut p_dense = params.clone();
        let mut run_dense = || {
            p_dense.clone_from(&params);
            let mut stats = EmStats::zeros_like(&p_dense);
            let mut logp = vec![0.0f32; batch];
            let mut b0 = 0;
            while b0 < n {
                let bn = batch.min(n - b0);
                let xs = data.rows(b0, b0 + bn);
                dense.forward(&p_dense, xs, &mask, &mut logp[..bn]);
                dense.backward(&p_dense, xs, &mask, bn, &mut stats);
                m_step(&mut p_dense, &stats, &em);
                stats.reset();
                b0 += bn;
            }
        };
        run_dense(); // warmup + establish timing scale
        let md = time_it(run_dense, 0, if quick { 2 } else { 3 });

        // ---- sparse (LibSPN/SPFlow-style) ------------------------------
        let mut sparse = SparseEngine::new(plan.clone(), family, batch);
        let mut p_sparse = params.clone();
        let mut run_sparse = || {
            p_sparse.clone_from(&params);
            let mut stats = EmStats::zeros_like(&p_sparse);
            let mut logp = vec![0.0f32; batch];
            let mut b0 = 0;
            while b0 < n {
                let bn = batch.min(n - b0);
                let xs = data.rows(b0, b0 + bn);
                sparse.forward(&p_sparse, xs, &mask, &mut logp[..bn]);
                sparse.backward(&p_sparse, xs, &mask, bn, &mut stats);
                m_step(&mut p_sparse, &stats, &em);
                stats.reset();
                b0 += bn;
            }
        };
        run_sparse();
        let ms = time_it(run_sparse, 0, if quick { 2 } else { 3 });

        let mem_d = dense.memory_footprint(&params).total();
        let mem_s = sparse.memory_footprint(&params).total();
        table.row(vec![
            pt.label.clone(),
            format!("{}", params.num_params()),
            fmt_si(md.median_s),
            fmt_si(ms.median_s),
            format!("{:.1}x", ms.median_s / md.median_s),
            fmt_bytes(mem_d),
            fmt_bytes(mem_s),
            format!("{:.1}x", mem_s as f64 / mem_d as f64),
        ]);
        println!(
            "{:<6} dense {} sparse {} speedup {:.1}x  mem {} vs {}",
            pt.label,
            fmt_si(md.median_s),
            fmt_si(ms.median_s),
            ms.median_s / md.median_s,
            fmt_bytes(mem_d),
            fmt_bytes(mem_s)
        );
        report_rows.push(json::obj(vec![
            ("point", json::s(&pt.label)),
            ("params", json::num(params.num_params() as f64)),
            ("dense_epoch_s", json::num(md.median_s)),
            ("sparse_epoch_s", json::num(ms.median_s)),
            ("speedup", json::num(ms.median_s / md.median_s)),
            ("dense_mem_bytes", json::num(mem_d as f64)),
            ("sparse_mem_bytes", json::num(mem_s as f64)),
        ]));
    }
    println!("\n{}", table.render());

    // ---- worker pool vs per-batch thread spawning ----------------------
    // Fig. 3 default config (K=10 D=4 R=10), multi-worker: the persistent
    // pool in coordinator::train_parallel against the old design that
    // re-spawned scoped threads every mini-batch.
    let workers = 4usize;
    let epochs = if quick { 2 } else { 3 };
    let graph = einet::structure::random_binary_trees(num_vars, 4, 10, 7);
    let plan = LayeredPlan::compile(graph, 10);
    let params0 = EinetParams::init(&plan, family, 0);

    let mut p_pool = params0.clone();
    let cfg = TrainConfig {
        epochs,
        batch_size: batch,
        workers,
        em,
        log_every: 0,
        ..Default::default()
    };
    let m_pool = time_it(
        || {
            p_pool.clone_from(&params0);
            train_parallel::<DenseEngine>(&plan, family, &mut p_pool, &data.data, n, &cfg);
        },
        1,
        if quick { 2 } else { 3 },
    );

    let shard_cap = batch.div_ceil(workers);
    let mut p_spawn = params0.clone();
    let m_spawn = time_it(
        || {
            // engine construction inside the timed region on BOTH sides
            // (train_parallel builds its worker engines per call too), so
            // the comparison isolates thread churn
            let mut engines: Vec<DenseEngine> = (0..workers)
                .map(|_| DenseEngine::new(plan.clone(), family, shard_cap))
                .collect();
            p_spawn.clone_from(&params0);
            for _ in 0..epochs {
                train_epoch_spawn_per_batch(
                    &plan, family, &mut p_spawn, &mut engines, &data.data, n, batch,
                    &em,
                );
            }
        },
        1,
        if quick { 2 } else { 3 },
    );
    let pool_speedup = m_spawn.median_s / m_pool.median_s;
    println!(
        "coordinator: persistent pool {} vs per-batch spawn {} ({:.2}x), \
         {workers} workers, {epochs} epochs",
        fmt_si(m_pool.median_s),
        fmt_si(m_spawn.median_s),
        pool_speedup
    );

    // ---- dense forward throughput on the Fig. 3 default config ---------
    // (recorded so future engine changes can be regression-checked)
    let mut fwd_engine = DenseEngine::new(plan.clone(), family, batch);
    let mut logp = vec![0.0f32; batch];
    let xs = data.rows(0, batch);
    let m_fwd = time_it(
        || fwd_engine.forward(&params0, xs, &mask, &mut logp),
        2,
        if quick { 5 } else { 10 },
    );
    let samples_per_s = batch as f64 / m_fwd.median_s;
    println!(
        "dense forward (K=10 D=4 R=10, batch {batch}): {} per batch ({:.0} samples/s)",
        fmt_si(m_fwd.median_s),
        samples_per_s
    );

    let report = json::obj(vec![
        ("experiment", json::s("fig3_train")),
        ("quick", json::num(quick as i32 as f64)),
        ("num_vars", json::num(num_vars as f64)),
        ("n", json::num(n as f64)),
        ("batch", json::num(batch as f64)),
        ("rows", json::arr(report_rows)),
        (
            "coordinator",
            json::obj(vec![
                ("workers", json::num(workers as f64)),
                ("epochs", json::num(epochs as f64)),
                ("persistent_pool_s", json::num(m_pool.median_s)),
                ("spawn_per_batch_s", json::num(m_spawn.median_s)),
                ("pool_speedup", json::num(pool_speedup)),
            ]),
        ),
        (
            "dense_forward",
            json::obj(vec![
                ("batch_s", json::num(m_fwd.median_s)),
                ("samples_per_s", json::num(samples_per_s)),
            ]),
        ),
    ]);
    std::fs::write("BENCH_fig3.json", report.to_string()).expect("write BENCH_fig3.json");
    println!("wrote BENCH_fig3.json");
}
