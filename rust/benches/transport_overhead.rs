//! Transport overhead: forward-serving throughput of the [`ShardedPool`]
//! over its two carriers — in-process channel workers vs loopback-TCP
//! workers (the [`spawn_loopback_workers`] stand-in for real
//! `einet shard-worker` processes) — at 1 / 2 / 4 shards on the dense
//! engine. The two pools run the identical cut of the identical plan, so
//! the reported ratio isolates the wire: frame encode/decode plus one
//! loopback round-trip per shard per batch.
//!
//! Results land in BENCH_transport.json (CI artifact).
//!
//!     cargo bench --bench transport_overhead            # full size
//!     EINET_BENCH_QUICK=1 cargo bench --bench transport_overhead

use einet::bench::{time_it, Table};
use einet::coordinator::transport::spawn_loopback_workers;
use einet::coordinator::ShardedPool;
use einet::data::debd::gaussian_noise;
use einet::util::json;
use einet::{boxed_build, DenseEngine, EinetParams, LayeredPlan, LeafFamily, Semiring};

/// Forward-only serving throughput of one pool over the whole dataset.
fn serve_rate(
    pool: &mut ShardedPool,
    data: &std::sync::Arc<Vec<f32>>,
    mask: &std::sync::Arc<Vec<f32>>,
    n: usize,
    batch: usize,
    reps: usize,
) -> f64 {
    let mut logp = vec![0.0f32; batch];
    let mut run = || {
        let mut b0 = 0usize;
        while b0 < n {
            let bn = batch.min(n - b0);
            pool.forward_shared(
                data.clone(),
                b0,
                mask.clone(),
                bn,
                Semiring::SumProduct,
                &mut logp[..bn],
            )
            .expect("shard worker failed mid-bench");
            b0 += bn;
        }
    };
    run(); // warmup
    let t = time_it(&mut run, 0, reps);
    n as f64 / t.median_s
}

fn main() {
    let quick = std::env::var("EINET_BENCH_QUICK").is_ok();
    let (num_vars, depth, replica, k) = if quick { (64, 3, 4, 4) } else { (256, 3, 8, 8) };
    let n = if quick { 100 } else { 300 };
    let batch = 50usize;
    let reps = if quick { 2 } else { 3 };
    let seed = 0u64;
    let structure = format!("rat:depth={depth},replica={replica},seed={seed}");
    let family = LeafFamily::Gaussian { channels: 1 };

    let graph = einet::structure::from_spec(num_vars, &structure).expect("structure");
    let plan = LayeredPlan::compile(graph, k);
    let params = EinetParams::init(&plan, family, 0);
    let data = std::sync::Arc::new(gaussian_noise(n, num_vars, 0).data);
    let mask = std::sync::Arc::new(vec![1.0f32; num_vars]);

    println!(
        "transport overhead — RAT D={num_vars} depth={depth} R={replica} K={k}, \
         N={n}, batch={batch} ({} params)",
        params.num_params()
    );
    let mut table = Table::new(&[
        "shards", "in-process rows/s", "loopback-TCP rows/s", "tcp/in-process",
    ]);
    let mut rows: Vec<json::Json> = Vec::new();
    for shards in [1usize, 2, 4] {
        let mut inproc = ShardedPool::new(
            boxed_build::<DenseEngine>,
            &plan,
            family,
            &params,
            shards,
            batch,
        );
        let r_in = serve_rate(&mut inproc, &data, &mask, n, batch, reps);
        inproc.stop();

        let (addrs, handles) =
            spawn_loopback_workers(shards).expect("spawn loopback workers");
        let mut tcp = ShardedPool::connect(
            &addrs, &structure, "dense", &plan, family, &params, shards, batch,
        )
        .expect("connect loopback pool");
        let r_tcp = serve_rate(&mut tcp, &data, &mask, n, batch, reps);
        tcp.stop();
        for h in handles {
            let _ = h.join();
        }

        let ratio = r_tcp / r_in;
        table.row(vec![
            format!("{shards}"),
            format!("{r_in:.0}"),
            format!("{r_tcp:.0}"),
            format!("{ratio:.2}x"),
        ]);
        println!(
            "x{shards}: in-process {r_in:.0} rows/s, loopback TCP {r_tcp:.0} rows/s \
             ({ratio:.2}x)"
        );
        rows.push(json::obj(vec![
            ("shards", json::num(shards as f64)),
            ("inproc_rows_per_s", json::num(r_in)),
            ("tcp_rows_per_s", json::num(r_tcp)),
            ("tcp_over_inproc", json::num(ratio)),
        ]));
    }
    println!("\n{}", table.render());

    let report = json::obj(vec![
        ("experiment", json::s("transport_overhead")),
        ("quick", json::num(quick as i32 as f64)),
        ("num_vars", json::num(num_vars as f64)),
        ("depth", json::num(depth as f64)),
        ("replica", json::num(replica as f64)),
        ("k", json::num(k as f64)),
        ("n", json::num(n as f64)),
        ("batch", json::num(batch as f64)),
        ("rows", json::arr(rows)),
    ]);
    std::fs::write("BENCH_transport.json", report.to_string())
        .expect("write BENCH_transport.json");
    println!("wrote BENCH_transport.json");
}
