//! Monarch weight-structure scaling: dense vs Monarch-factorized sum
//! layers at K ∈ {32, 64, 128} on the same RAT structure — stochastic-EM
//! training rows/s, parameter counts, and train-LL-per-parameter.
//!
//! The point of comparison the report pins: one logical `[K, K]` sum
//! block stores `K²` scalars dense but only `K·(K/b + b)` under
//! `monarch:b`, so a Monarch block at K=128 (3072 weights at b=16) is
//! *smaller* than a dense block at K=64 (4096) while mixing a 4× larger
//! product space — the width regime dense K² pricing cannot reach.
//! `tests/monarch_oracle.rs` pins the numerics; this bench records only
//! cost. Results land in BENCH_monarch.json (CI artifact; schema in
//! docs/BENCHMARKS.md).
//!
//!     cargo bench --bench monarch_scaling            # full size
//!     EINET_BENCH_QUICK=1 cargo bench --bench monarch_scaling

use einet::bench::{time_it, Table};
use einet::em::{m_step, EmConfig};
use einet::util::json;
use einet::util::rng::Rng;
use einet::{
    DenseEngine, EinetParams, EmStats, Engine, LayeredPlan, LeafFamily,
    WeightStructure,
};

struct Point {
    spec: String,
    k: usize,
    block_params: usize,
    sum_params: usize,
    total_params: usize,
    rows_per_s: f64,
    train_ll: f64,
}

#[allow(clippy::too_many_arguments)]
fn run_point(
    nv: usize,
    depth: usize,
    replica: usize,
    k: usize,
    ws: WeightStructure,
    data: &[f32],
    n: usize,
    batch: usize,
    reps: usize,
) -> Point {
    let graph = einet::structure::random_binary_trees(nv, depth, replica, 7);
    let plan = LayeredPlan::compile(graph, k)
        .with_weight_structure(ws)
        .expect("valid structure for this K");
    let family = LeafFamily::Bernoulli;
    let params0 = EinetParams::init(&plan, family, 0);
    let mask = vec![1.0f32; nv];
    let em = EmConfig { step_size: 0.5, ..Default::default() };

    let mut engine = DenseEngine::new(plan.clone(), family, batch);
    let mut params = params0.clone();
    let mut logp = vec![0.0f32; batch];
    // one epoch of stochastic EM = the timed unit
    let mut run_epoch = |params: &mut EinetParams| {
        let mut b0 = 0usize;
        while b0 < n {
            let bn = batch.min(n - b0);
            let xs = &data[b0 * nv..(b0 + bn) * nv];
            engine.forward(params, xs, &mask, &mut logp[..bn]);
            let mut stats = EmStats::zeros_like(params);
            engine.backward(params, xs, &mask, bn, &mut stats);
            m_step(params, &stats, &em);
            b0 += bn;
        }
    };
    run_epoch(&mut params); // warmup (and one real step of progress)
    let m = time_it(|| run_epoch(&mut params), 0, reps);

    // trained-model average LL over the training rows
    let mut total = 0.0f64;
    let mut b0 = 0usize;
    while b0 < n {
        let bn = batch.min(n - b0);
        engine.forward(&params, &data[b0 * nv..(b0 + bn) * nv], &mask, &mut logp[..bn]);
        total += logp[..bn].iter().map(|&l| l as f64).sum::<f64>();
        b0 += bn;
    }
    Point {
        spec: ws.spec(),
        k,
        block_params: ws.params_per_block(k),
        sum_params: plan.num_sum_params(),
        total_params: params.num_params(),
        rows_per_s: n as f64 / m.median_s,
        train_ll: total / n as f64,
    }
}

fn main() {
    let quick = std::env::var("EINET_BENCH_QUICK").is_ok();
    let (nv, depth, replica) = if quick { (16, 2, 2) } else { (32, 2, 4) };
    let n = if quick { 96 } else { 256 };
    let batch = if quick { 32 } else { 64 };
    let reps = if quick { 1 } else { 2 };
    let mut rng = Rng::new(3);
    let data: Vec<f32> = (0..n * nv)
        .map(|_| if rng.bernoulli(0.5) { 1.0 } else { 0.0 })
        .collect();

    println!(
        "monarch scaling — RAT D={nv} depth={depth} R={replica}, N={n}, batch={batch}"
    );
    let mut table = Table::new(&[
        "structure", "K", "block params", "sum params", "total params",
        "train rows/s", "train LL",
    ]);
    let mut rows: Vec<json::Json> = Vec::new();
    let mut block_params = std::collections::BTreeMap::new();
    for &k in &[32usize, 64, 128] {
        let monarch = WeightStructure::parse("monarch", k).expect("composite K");
        for ws in [WeightStructure::Dense, monarch] {
            let p = run_point(nv, depth, replica, k, ws, &data, n, batch, reps);
            println!(
                "{:<10} K={k}: {} weights/block, {} sum params, {:.0} rows/s, LL {:.4}",
                p.spec, p.block_params, p.sum_params, p.rows_per_s, p.train_ll
            );
            table.row(vec![
                p.spec.clone(),
                format!("{k}"),
                format!("{}", p.block_params),
                format!("{}", p.sum_params),
                format!("{}", p.total_params),
                format!("{:.0}", p.rows_per_s),
                format!("{:.4}", p.train_ll),
            ]);
            block_params.insert((p.spec.starts_with("monarch"), k), p.block_params);
            rows.push(json::obj(vec![
                ("structure", json::s(&p.spec)),
                ("k", json::num(p.k as f64)),
                ("block_params", json::num(p.block_params as f64)),
                ("sum_params", json::num(p.sum_params as f64)),
                ("total_params", json::num(p.total_params as f64)),
                ("train_rows_per_s", json::num(p.rows_per_s)),
                ("train_ll", json::num(p.train_ll)),
                (
                    "ll_per_kparam",
                    json::num(p.train_ll * 1000.0 / p.total_params as f64),
                ),
            ]));
        }
    }
    println!("\n{}", table.render());

    // the acceptance comparison: one Monarch K=128 sum block is smaller
    // than one dense K=64 sum block
    let m128 = block_params[&(true, 128)] as f64;
    let d64 = block_params[&(false, 64)] as f64;
    println!(
        "per sum block: monarch K=128 stores {m128} weights vs dense K=64's {d64} \
         (dense K=128 would need {})",
        128 * 128
    );
    let report = json::obj(vec![
        ("experiment", json::s("monarch_scaling")),
        ("quick", json::num(quick as i32 as f64)),
        ("num_vars", json::num(nv as f64)),
        ("depth", json::num(depth as f64)),
        ("replica", json::num(replica as f64)),
        ("n", json::num(n as f64)),
        ("batch", json::num(batch as f64)),
        ("rows", json::arr(rows)),
        ("monarch_k128_block_params", json::num(m128)),
        ("dense_k64_block_params", json::num(d64)),
        (
            "monarch_k128_smaller_than_dense_k64",
            json::num((m128 < d64) as i32 as f64),
        ),
    ]);
    std::fs::write("BENCH_monarch.json", report.to_string())
        .expect("write BENCH_monarch.json");
    println!("wrote BENCH_monarch.json");
}
