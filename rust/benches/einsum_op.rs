//! Section 3.2 micro-benchmark: the basic einsum operation in isolation,
//! plus the kernel-layout sweep behind `BENCH_kernels.json`.
//!
//! Part 1 (the paper's op-count analysis): for one vectorized sum-product
//! with children of length K,
//!   dense  (Eq. 4): O(K^3) mul-adds, 2K exp, K log, NO product storage
//!   sparse (LibSPN/SPFlow style): O(K^3) adds, K^3 exp, K log, K^2 stored
//! This isolates exactly that unit over a K sweep to show where the
//! crossover in exp-ops vs mul-adds lands on CPU.
//!
//! Part 2 (the systems sweep): the SAME dense einsum step at batch
//! B = 256, three layouts —
//!   per-row scalar   : row-major product + per-row `dot4`/`max4`
//!                      (the pre-kernel engine path: the weight slot is
//!                      re-streamed once per batch row)
//!   blocked scalar   : transposed [K², b_blk] operand + the portable
//!                      4-lane-chunked `einsum_block`
//!   blocked SIMD     : the same blocked kernel on the detected ISA
//!                      (AVX2 / NEON)
//! — with `b_blk` autotuned per K ([`kernels::tune_block_rows`], the
//! value the engines record in their `ExecPlan`). All three start from
//! the same scaled-exponential children (the 2K exps and K logs per row
//! are identical across layouts and included in every timing), and all
//! three are asserted bit-identical before timing. The full step is then
//! A/B'd across the two math tiers IN ONE PROCESS AND ONE RUN — `exact`
//! (libm, the default) vs `fast` (the vectorized polynomial `vexp`/`vln`
//! tier) — so every BENCH_kernels.json entry carries both tiers'
//! `step_exact_*`/`step_fast_*` columns plus the speedup ratio.
//!
//! Part 3 (the transcendental split): per forward step kind — leaf
//! normalizer/emission, einsum, mixing — the full step in both tiers
//! next to a transcendental-free skeleton of the same loop, giving the
//! exp/ln *fraction* each step kind pays and what the fast tier buys it.
//! Results go to stdout and BENCH_kernels.json (schema documented in
//! docs/BENCHMARKS.md).
//!
//!     cargo bench --bench einsum_op
//!     EINET_BENCH_QUICK=1 cargo bench --bench einsum_op   # CI quick mode

use einet::bench::{fmt_si, time_it, Table};
use einet::engine::exec::Semiring;
use einet::engine::kernels::{self, Isa, MathTier};
use einet::util::json;
use einet::util::rng::Rng;

/// dense: log-einsum-exp (Eq. 4)
fn dense_op(logn: &[f32], lognp: &[f32], w: &[f32], k: usize, out: &mut [f32]) {
    let mut a = f32::NEG_INFINITY;
    let mut ap = f32::NEG_INFINITY;
    for i in 0..k {
        a = a.max(logn[i]);
        ap = ap.max(lognp[i]);
    }
    // en/enp in stack buffers
    let mut en = vec![0.0f32; k];
    let mut enp = vec![0.0f32; k];
    for i in 0..k {
        en[i] = (logn[i] - a).exp();
        enp[i] = (lognp[i] - ap).exp();
    }
    for ko in 0..k {
        let wrow = &w[ko * k * k..(ko + 1) * k * k];
        let mut acc = 0.0f32;
        for i in 0..k {
            let eni = en[i];
            let wr = &wrow[i * k..(i + 1) * k];
            let mut dot = 0.0f32;
            for j in 0..k {
                dot += wr[j] * enp[j];
            }
            acc += eni * dot;
        }
        out[ko] = a + ap + acc.ln();
    }
}

/// sparse: explicit outer-sum product + broadcast logw + K^2 logsumexp
fn sparse_op(
    logn: &[f32],
    lognp: &[f32],
    logw: &[f32],
    k: usize,
    prod: &mut [f32],
    out: &mut [f32],
) {
    for i in 0..k {
        for j in 0..k {
            prod[i * k + j] = logn[i] + lognp[j];
        }
    }
    for ko in 0..k {
        let wrow = &logw[ko * k * k..(ko + 1) * k * k];
        let mut m = f32::NEG_INFINITY;
        for idx in 0..k * k {
            m = m.max(wrow[idx] + prod[idx]);
        }
        let mut s = 0.0f32;
        for idx in 0..k * k {
            s += (wrow[idx] + prod[idx] - m).exp();
        }
        out[ko] = m + s.ln();
    }
}

/// One full einsum step over the batch, per-row layout: per row compute
/// the scaled children, the row-major K² product, then Ko `dot4`/`max4`
/// reductions + logs — exactly what the engines did before the blocked
/// kernels.
#[allow(clippy::too_many_arguments)]
fn step_per_row(
    sr: Semiring,
    logn: &[f32],
    lognp: &[f32],
    w: &[f32],
    k: usize,
    ko: usize,
    bn: usize,
    en: &mut [f32],
    enp: &mut [f32],
    prod: &mut [f32],
    out: &mut [f32],
) {
    let k2 = k * k;
    for b in 0..bn {
        let lrow = &logn[b * k..(b + 1) * k];
        let rrow = &lognp[b * k..(b + 1) * k];
        let mut a = f32::NEG_INFINITY;
        let mut ap = f32::NEG_INFINITY;
        for kk in 0..k {
            a = a.max(lrow[kk]);
            ap = ap.max(rrow[kk]);
        }
        for kk in 0..k {
            en[kk] = (lrow[kk] - a).exp();
            enp[kk] = (rrow[kk] - ap).exp();
        }
        for (ii, &eni) in en.iter().enumerate() {
            for (p, &enpj) in prod[ii * k..(ii + 1) * k].iter_mut().zip(enp.iter()) {
                *p = eni * enpj;
            }
        }
        let base = a + ap;
        for kout in 0..ko {
            let wrow = &w[kout * k2..(kout + 1) * k2];
            let acc = match sr {
                Semiring::SumProduct => kernels::dot4(Isa::Scalar, wrow, prod),
                Semiring::MaxProduct => kernels::max4(Isa::Scalar, wrow, prod),
            };
            out[b * ko + kout] = base + acc.ln();
        }
    }
}

/// The same step through the blocked kernels under `isa` and `math` —
/// exactly the engine's `fwd_einsum` shape: per block of `b_blk` rows
/// stage the scaled-child *arguments* transposed, sweep them with
/// [`kernels::vexp`], run `outer_block` + `einsum_block`, return to the
/// log domain with [`kernels::vln`], and add the row maxima back. Under
/// [`MathTier::Exact`] the sweeps replay libm per element, so the output
/// is bit-identical to [`step_per_row`].
#[allow(clippy::too_many_arguments)]
fn step_blocked(
    isa: Isa,
    math: MathTier,
    sr: Semiring,
    logn: &[f32],
    lognp: &[f32],
    w: &[f32],
    k: usize,
    ko: usize,
    bn: usize,
    b_blk: usize,
    en_t: &mut [f32],
    enp_t: &mut [f32],
    prod_t: &mut [f32],
    acc: &mut [f32],
    base: &mut [f32],
    out: &mut [f32],
) {
    let k2 = k * k;
    let mut b0 = 0usize;
    while b0 < bn {
        let bb = b_blk.min(bn - b0);
        for j in 0..bb {
            let b = b0 + j;
            let lrow = &logn[b * k..(b + 1) * k];
            let rrow = &lognp[b * k..(b + 1) * k];
            let mut a = f32::NEG_INFINITY;
            let mut ap = f32::NEG_INFINITY;
            for kk in 0..k {
                a = a.max(lrow[kk]);
                ap = ap.max(rrow[kk]);
            }
            base[j] = a + ap;
            for kk in 0..k {
                en_t[kk * bb + j] = lrow[kk] - a;
                enp_t[kk * bb + j] = rrow[kk] - ap;
            }
        }
        kernels::vexp(isa, math, &mut en_t[..k * bb]);
        kernels::vexp(isa, math, &mut enp_t[..k * bb]);
        kernels::outer_block(isa, en_t, enp_t, k, bb, prod_t);
        kernels::einsum_block(isa, sr, w, prod_t, k2, ko, bb, acc);
        kernels::vln(isa, math, &mut acc[..ko * bb]);
        for j in 0..bb {
            for kout in 0..ko {
                out[(b0 + j) * ko + kout] = base[j] + acc[kout * bb + j];
            }
        }
        b0 += bb;
    }
}

fn part1_dense_vs_sparse(quick: bool, report_rows: &mut Vec<json::Json>) {
    let mut rng = Rng::new(0);
    println!("Section 3.2 — basic einsum op, dense (Eq. 4) vs sparse workaround");
    let mut table = Table::new(&["K", "dense", "sparse", "speedup", "max |diff|"]);
    let ks: &[usize] = if quick { &[4, 8, 16] } else { &[2, 4, 8, 16, 32, 64] };
    for &k in ks {
        let logn: Vec<f32> = (0..k).map(|_| rng.normal() as f32 - 2.0).collect();
        let lognp: Vec<f32> = (0..k).map(|_| rng.normal() as f32 - 2.0).collect();
        let mut w: Vec<f32> = (0..k * k * k)
            .map(|_| rng.uniform_in(0.01, 1.0) as f32)
            .collect();
        for block in w.chunks_mut(k * k) {
            let total: f32 = block.iter().sum();
            for v in block.iter_mut() {
                *v /= total;
            }
        }
        let logw: Vec<f32> = w.iter().map(|&v| v.ln()).collect();
        let mut out_d = vec![0.0f32; k];
        let mut out_s = vec![0.0f32; k];
        let mut prod = vec![0.0f32; k * k];
        let reps = 512.max(65536 / (k * k));
        let timing_reps = if quick { 3 } else { 5 };
        let md = time_it(
            || {
                for _ in 0..reps {
                    dense_op(&logn, &lognp, &w, k, &mut out_d);
                    std::hint::black_box(&out_d);
                }
            },
            1,
            timing_reps,
        );
        let ms = time_it(
            || {
                for _ in 0..reps {
                    sparse_op(&logn, &lognp, &logw, k, &mut prod, &mut out_s);
                    std::hint::black_box(&out_s);
                }
            },
            1,
            timing_reps,
        );
        let diff = out_d
            .iter()
            .zip(&out_s)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        table.row(vec![
            format!("{k}"),
            fmt_si(md.median_s / reps as f64),
            fmt_si(ms.median_s / reps as f64),
            format!("{:.1}x", ms.median_s / md.median_s),
            format!("{diff:.2e}"),
        ]);
        println!(
            "K={k:<3} dense {}  sparse {}  speedup {:.1}x  diff {diff:.1e}",
            fmt_si(md.median_s / reps as f64),
            fmt_si(ms.median_s / reps as f64),
            ms.median_s / md.median_s
        );
        assert!(diff < 1e-3, "layouts disagree");
        report_rows.push(json::obj(vec![
            ("k", json::num(k as f64)),
            ("dense_op_s", json::num(md.median_s / reps as f64)),
            ("sparse_op_s", json::num(ms.median_s / reps as f64)),
            ("sparse_vs_dense", json::num(ms.median_s / md.median_s)),
        ]));
    }
    println!("\n{}", table.render());
}

/// Kernel-only, per-row layout: from precomputed scaled children, build
/// each row's K² product and run Ko `dot4`/`max4` reductions — the
/// contraction exactly as the pre-kernel engines executed it (linear
/// domain; the identical exp/ln plumbing around it is timed separately
/// in the `step_*` figures).
#[allow(clippy::too_many_arguments)]
fn kernel_per_row(
    sr: Semiring,
    en_all: &[f32],
    enp_all: &[f32],
    w: &[f32],
    k: usize,
    ko: usize,
    bn: usize,
    prod: &mut [f32],
    out: &mut [f32],
) {
    let k2 = k * k;
    for b in 0..bn {
        let en = &en_all[b * k..(b + 1) * k];
        let enp = &enp_all[b * k..(b + 1) * k];
        for (ii, &eni) in en.iter().enumerate() {
            for (p, &enpj) in prod[ii * k..(ii + 1) * k].iter_mut().zip(enp.iter()) {
                *p = eni * enpj;
            }
        }
        for kout in 0..ko {
            let wrow = &w[kout * k2..(kout + 1) * k2];
            out[b * ko + kout] = match sr {
                Semiring::SumProduct => kernels::dot4(Isa::Scalar, wrow, prod),
                Semiring::MaxProduct => kernels::max4(Isa::Scalar, wrow, prod),
            };
        }
    }
}

/// Kernel-only, blocked layout: `outer_block` + `einsum_block` per
/// `b_blk`-row block over block-transposed children (block bases
/// `b_blk`-strided, values packed at each block's actual width).
#[allow(clippy::too_many_arguments)]
fn kernel_blocked(
    isa: Isa,
    sr: Semiring,
    en_t_all: &[f32],
    enp_t_all: &[f32],
    w: &[f32],
    k: usize,
    ko: usize,
    bn: usize,
    b_blk: usize,
    prod_t: &mut [f32],
    acc: &mut [f32],
    out: &mut [f32],
) {
    let k2 = k * k;
    let mut b0 = 0usize;
    while b0 < bn {
        let bb = b_blk.min(bn - b0);
        let blk = (b0 / b_blk) * k * b_blk;
        kernels::outer_block(
            isa,
            &en_t_all[blk..blk + k * bb],
            &enp_t_all[blk..blk + k * bb],
            k,
            bb,
            prod_t,
        );
        kernels::einsum_block(isa, sr, w, prod_t, k2, ko, bb, acc);
        for j in 0..bb {
            for kout in 0..ko {
                out[(b0 + j) * ko + kout] = acc[kout * bb + j];
            }
        }
        b0 += bb;
    }
}

fn sr_tag(sr: Semiring) -> &'static str {
    match sr {
        Semiring::SumProduct => "sum",
        Semiring::MaxProduct => "max",
    }
}

fn part2_kernel_sweep(quick: bool, report_rows: &mut Vec<json::Json>) {
    let isa = Isa::best();
    let batch = 256usize;
    println!(
        "Kernel sweep — per-row scalar vs blocked scalar vs blocked {} \
         (B={batch}, b_blk autotuned per K, exact vs fast tier A/B)",
        isa.name()
    );
    let mut table = Table::new(&[
        "K",
        "b_blk",
        "semiring",
        "kernel/row",
        "kernel/blocked",
        "kernel/simd",
        "simd vs row",
        "step exact",
        "step fast",
        "fast vs exact",
    ]);
    let ks: &[usize] = if quick { &[4, 8, 10, 16] } else { &[2, 4, 8, 10, 16, 32] };
    for &k in ks {
        let ko = k;
        let k2 = k * k;
        let b_blk = kernels::tune_block_rows(k, batch, isa);
        let mut rng = Rng::new(7 + k as u64);
        let logn: Vec<f32> = (0..batch * k)
            .map(|_| rng.uniform_in(-8.0, 0.0) as f32)
            .collect();
        let lognp: Vec<f32> = (0..batch * k)
            .map(|_| rng.uniform_in(-8.0, 0.0) as f32)
            .collect();
        let mut w: Vec<f32> = (0..ko * k2)
            .map(|_| rng.uniform_in(0.01, 1.0) as f32)
            .collect();
        for block in w.chunks_mut(k2) {
            let total: f32 = block.iter().sum();
            for v in block.iter_mut() {
                *v /= total;
            }
        }
        // precompute scaled children once, in both layouts (row-major and
        // block-transposed) — they are byte-for-byte the same values
        let mut en_all = vec![0.0f32; batch * k];
        let mut enp_all = vec![0.0f32; batch * k];
        for b in 0..batch {
            let lrow = &logn[b * k..(b + 1) * k];
            let rrow = &lognp[b * k..(b + 1) * k];
            let mut a = f32::NEG_INFINITY;
            let mut ap = f32::NEG_INFINITY;
            for kk in 0..k {
                a = a.max(lrow[kk]);
                ap = ap.max(rrow[kk]);
            }
            for kk in 0..k {
                en_all[b * k + kk] = (lrow[kk] - a).exp();
                enp_all[b * k + kk] = (rrow[kk] - ap).exp();
            }
        }
        // block bases are b_blk-strided, but *within* a block values are
        // packed at that block's actual width (the tail block is narrower
        // when b_blk does not divide the batch) — the layout
        // `kernel_blocked` consumes
        let nblocks = batch.div_ceil(b_blk);
        let mut en_t_all = vec![0.0f32; nblocks * k * b_blk];
        let mut enp_t_all = vec![0.0f32; nblocks * k * b_blk];
        for b in 0..batch {
            let (bi, j) = (b / b_blk, b % b_blk);
            let bb = b_blk.min(batch - bi * b_blk);
            for kk in 0..k {
                en_t_all[bi * k * b_blk + kk * bb + j] = en_all[b * k + kk];
                enp_t_all[bi * k * b_blk + kk * bb + j] = enp_all[b * k + kk];
            }
        }
        let mut prod = vec![0.0f32; k2];
        let mut en = vec![0.0f32; k];
        let mut enp = vec![0.0f32; k];
        let mut prod_t = vec![0.0f32; k2 * b_blk];
        let mut acc = vec![0.0f32; ko * b_blk];
        let mut base = vec![0.0f32; b_blk];
        let mut out_row = vec![0.0f32; batch * ko];
        let mut out_blk = vec![0.0f32; batch * ko];
        let mut out_simd = vec![0.0f32; batch * ko];
        let timing_reps = if quick { 5 } else { 9 };
        let mut row = vec![
            ("k", json::num(k as f64)),
            ("ko", json::num(ko as f64)),
            ("batch", json::num(batch as f64)),
            ("b_blk", json::num(b_blk as f64)),
            ("isa", json::s(isa.name())),
            (
                "tiers",
                json::arr(vec![json::s("exact"), json::s("fast")]),
            ),
        ];
        for sr in [Semiring::SumProduct, Semiring::MaxProduct] {
            // correctness first: all three contraction paths bit-identical
            kernel_per_row(sr, &en_all, &enp_all, &w, k, ko, batch, &mut prod, &mut out_row);
            kernel_blocked(
                Isa::Scalar, sr, &en_t_all, &enp_t_all, &w, k, ko, batch, b_blk,
                &mut prod_t, &mut acc, &mut out_blk,
            );
            kernel_blocked(
                isa, sr, &en_t_all, &enp_t_all, &w, k, ko, batch, b_blk,
                &mut prod_t, &mut acc, &mut out_simd,
            );
            for i in 0..batch * ko {
                assert_eq!(
                    out_row[i].to_bits(),
                    out_blk[i].to_bits(),
                    "per-row vs blocked diverge at K={k} {sr:?} [{i}]"
                );
                assert_eq!(
                    out_blk[i].to_bits(),
                    out_simd[i].to_bits(),
                    "blocked scalar vs SIMD diverge at K={k} {sr:?} [{i}]"
                );
            }
            // ... and so is the full Exact-tier step (exp prep +
            // contraction + ln): the tier default must not move a bit
            let mut en_t = vec![0.0f32; k * b_blk];
            let mut enp_t = vec![0.0f32; k * b_blk];
            step_per_row(
                sr, &logn, &lognp, &w, k, ko, batch, &mut en, &mut enp, &mut prod,
                &mut out_row,
            );
            step_blocked(
                isa, MathTier::Exact, sr, &logn, &lognp, &w, k, ko, batch, b_blk,
                &mut en_t, &mut enp_t, &mut prod_t, &mut acc, &mut base, &mut out_simd,
            );
            for i in 0..batch * ko {
                assert_eq!(
                    out_row[i].to_bits(),
                    out_simd[i].to_bits(),
                    "full step diverges at K={k} {sr:?} [{i}]"
                );
            }
            // the Fast tier trades bits for speed: hold it to the
            // engine-level drift bound instead
            let mut out_fast = vec![0.0f32; batch * ko];
            step_blocked(
                isa, MathTier::Fast, sr, &logn, &lognp, &w, k, ko, batch, b_blk,
                &mut en_t, &mut enp_t, &mut prod_t, &mut acc, &mut base, &mut out_fast,
            );
            for i in 0..batch * ko {
                let (a, b) = (out_simd[i], out_fast[i]);
                assert!(
                    (a - b).abs() < 1e-2 * (1.0 + a.abs()),
                    "fast tier drifted at K={k} {sr:?} [{i}]: {a} vs {b}"
                );
            }
            // kernel-only timings (the headline: the contraction itself)
            let t_row = time_it(
                || {
                    kernel_per_row(
                        sr, &en_all, &enp_all, &w, k, ko, batch, &mut prod, &mut out_row,
                    );
                    std::hint::black_box(&out_row);
                },
                2,
                timing_reps,
            );
            let t_blk = time_it(
                || {
                    kernel_blocked(
                        Isa::Scalar, sr, &en_t_all, &enp_t_all, &w, k, ko, batch, b_blk,
                        &mut prod_t, &mut acc, &mut out_blk,
                    );
                    std::hint::black_box(&out_blk);
                },
                2,
                timing_reps,
            );
            let t_simd = time_it(
                || {
                    kernel_blocked(
                        isa, sr, &en_t_all, &enp_t_all, &w, k, ko, batch, b_blk,
                        &mut prod_t, &mut acc, &mut out_simd,
                    );
                    std::hint::black_box(&out_simd);
                },
                2,
                timing_reps,
            );
            // full-step timings (exp prep + contraction + ln): what the
            // engine-level forward pays, transcendentals included
            let t_step_row = time_it(
                || {
                    step_per_row(
                        sr, &logn, &lognp, &w, k, ko, batch, &mut en, &mut enp, &mut prod,
                        &mut out_row,
                    );
                    std::hint::black_box(&out_row);
                },
                2,
                timing_reps,
            );
            let t_step_exact = time_it(
                || {
                    step_blocked(
                        isa, MathTier::Exact, sr, &logn, &lognp, &w, k, ko, batch, b_blk,
                        &mut en_t, &mut enp_t, &mut prod_t, &mut acc, &mut base, &mut out_simd,
                    );
                    std::hint::black_box(&out_simd);
                },
                2,
                timing_reps,
            );
            let t_step_fast = time_it(
                || {
                    step_blocked(
                        isa, MathTier::Fast, sr, &logn, &lognp, &w, k, ko, batch, b_blk,
                        &mut en_t, &mut enp_t, &mut prod_t, &mut acc, &mut base, &mut out_fast,
                    );
                    std::hint::black_box(&out_fast);
                },
                2,
                timing_reps,
            );
            let simd_vs_row = t_row.median_s / t_simd.median_s;
            let step_ratio = t_step_row.median_s / t_step_exact.median_s;
            let fast_vs_exact = t_step_exact.median_s / t_step_fast.median_s;
            let fast_vs_row = t_step_row.median_s / t_step_fast.median_s;
            // share of the Exact full step spent OUTSIDE the contraction
            // kernel: the exp/ln sweeps plus arg staging and write-back
            let transc_frac =
                ((t_step_exact.median_s - t_simd.median_s) / t_step_exact.median_s).max(0.0);
            let tag = sr_tag(sr);
            table.row(vec![
                format!("{k}"),
                format!("{b_blk}"),
                tag.into(),
                fmt_si(t_row.median_s),
                fmt_si(t_blk.median_s),
                fmt_si(t_simd.median_s),
                format!("{simd_vs_row:.2}x"),
                fmt_si(t_step_exact.median_s),
                fmt_si(t_step_fast.median_s),
                format!("{fast_vs_exact:.2}x"),
            ]);
            println!(
                "K={k:<3} {tag}: kernel row {} blocked {} {} {} ({simd_vs_row:.2}x); \
                 step row {} -> exact {} ({step_ratio:.2}x) -> fast {} \
                 ({fast_vs_exact:.2}x over exact, {fast_vs_row:.2}x over row, \
                 transc frac {transc_frac:.2})",
                fmt_si(t_row.median_s),
                fmt_si(t_blk.median_s),
                isa.name(),
                fmt_si(t_simd.median_s),
                fmt_si(t_step_row.median_s),
                fmt_si(t_step_exact.median_s),
                fmt_si(t_step_fast.median_s),
            );
            let key = |name: &'static str, alt: &'static str| -> &'static str {
                match sr {
                    Semiring::SumProduct => name,
                    Semiring::MaxProduct => alt,
                }
            };
            row.push((key("kernel_row_sum_s", "kernel_row_max_s"), json::num(t_row.median_s)));
            row.push((
                key("kernel_blocked_sum_s", "kernel_blocked_max_s"),
                json::num(t_blk.median_s),
            ));
            row.push((
                key("kernel_simd_sum_s", "kernel_simd_max_s"),
                json::num(t_simd.median_s),
            ));
            row.push((key("simd_vs_row_sum", "simd_vs_row_max"), json::num(simd_vs_row)));
            row.push((
                key("step_row_sum_s", "step_row_max_s"),
                json::num(t_step_row.median_s),
            ));
            row.push((
                key("step_exact_sum_s", "step_exact_max_s"),
                json::num(t_step_exact.median_s),
            ));
            row.push((
                key("step_fast_sum_s", "step_fast_max_s"),
                json::num(t_step_fast.median_s),
            ));
            row.push((
                key("step_exact_vs_row_sum", "step_exact_vs_row_max"),
                json::num(step_ratio),
            ));
            row.push((
                key("step_fast_vs_exact_sum", "step_fast_vs_exact_max"),
                json::num(fast_vs_exact),
            ));
            row.push((
                key("step_fast_vs_row_sum", "step_fast_vs_row_max"),
                json::num(fast_vs_row),
            ));
            row.push((
                key("transc_frac_sum", "transc_frac_max"),
                json::num(transc_frac),
            ));
        }
        report_rows.push(json::obj(row));
    }
    println!("\n{}", table.render());
}

/// Part 3: the transcendental split. For each forward step *kind* —
/// leaf log-normalizer, einsum, mixing — time the full step in both
/// math tiers next to a transcendental-free *skeleton* of the same loop
/// (identical staging, memory traffic, and reductions; only the exp/ln
/// sweeps elided). `transc frac` = (exact − skeleton) / exact is the
/// share of the step the transcendentals cost, the ceiling on what any
/// fast-math tier can recover.
fn part3_transcendental_split(quick: bool, report_rows: &mut Vec<json::Json>) {
    let isa = Isa::best();
    let batch = 256usize;
    let k = 10usize;
    let ko = k;
    let k2 = k * k;
    let timing_reps = if quick { 5 } else { 9 };
    println!(
        "\nTranscendental split — full step vs exp/ln-free skeleton per step kind \
         (K={k}, B={batch}, {})",
        isa.name()
    );
    let mut table = Table::new(&[
        "step kind", "exact", "fast", "skeleton", "transc frac", "fast vs exact",
    ]);
    let mut rng = Rng::new(99);
    let mut record = |kind: &'static str, exact_s: f64, fast_s: f64, skel_s: f64| {
        let frac = ((exact_s - skel_s) / exact_s).max(0.0);
        let ratio = exact_s / fast_s;
        table.row(vec![
            kind.into(),
            fmt_si(exact_s),
            fmt_si(fast_s),
            fmt_si(skel_s),
            format!("{frac:.2}"),
            format!("{ratio:.2}x"),
        ]);
        report_rows.push(json::obj(vec![
            ("kind", json::s(kind)),
            ("k", json::num(k as f64)),
            ("batch", json::num(batch as f64)),
            ("step_exact_s", json::num(exact_s)),
            ("step_fast_s", json::num(fast_s)),
            ("step_skeleton_s", json::num(skel_s)),
            ("transc_frac", json::num(frac)),
            ("fast_vs_exact", json::num(ratio)),
        ]));
    };

    // --- einsum: the blocked forward step (exp prep + contraction + ln)
    let b_blk = kernels::tune_block_rows(k, batch, isa);
    let logn: Vec<f32> = (0..batch * k).map(|_| rng.uniform_in(-8.0, 0.0) as f32).collect();
    let lognp: Vec<f32> = (0..batch * k).map(|_| rng.uniform_in(-8.0, 0.0) as f32).collect();
    let w: Vec<f32> = (0..ko * k2).map(|_| rng.uniform_in(0.01, 1.0) as f32).collect();
    let mut en_t = vec![0.0f32; k * b_blk];
    let mut enp_t = vec![0.0f32; k * b_blk];
    let mut prod_t = vec![0.0f32; k2 * b_blk];
    let mut acc = vec![0.0f32; ko * b_blk];
    let mut base = vec![0.0f32; b_blk];
    let mut out = vec![0.0f32; batch * ko];
    let mut time_einsum = |math: Option<MathTier>| -> f64 {
        time_it(
            || {
                match math {
                    Some(m) => step_blocked(
                        isa, m, Semiring::SumProduct, &logn, &lognp, &w, k, ko, batch,
                        b_blk, &mut en_t, &mut enp_t, &mut prod_t, &mut acc, &mut base,
                        &mut out,
                    ),
                    // skeleton: same staging and contraction, exp/ln elided
                    None => {
                        let mut b0 = 0usize;
                        while b0 < batch {
                            let bb = b_blk.min(batch - b0);
                            for j in 0..bb {
                                let b = b0 + j;
                                let lrow = &logn[b * k..(b + 1) * k];
                                let rrow = &lognp[b * k..(b + 1) * k];
                                let mut a = f32::NEG_INFINITY;
                                let mut ap = f32::NEG_INFINITY;
                                for kk in 0..k {
                                    a = a.max(lrow[kk]);
                                    ap = ap.max(rrow[kk]);
                                }
                                base[j] = a + ap;
                                for kk in 0..k {
                                    en_t[kk * bb + j] = lrow[kk] - a;
                                    enp_t[kk * bb + j] = rrow[kk] - ap;
                                }
                            }
                            kernels::outer_block(isa, &en_t, &enp_t, k, bb, &mut prod_t);
                            kernels::einsum_block(
                                isa, Semiring::SumProduct, &w, &prod_t, k2, ko, bb, &mut acc,
                            );
                            for j in 0..bb {
                                for kout in 0..ko {
                                    out[(b0 + j) * ko + kout] = base[j] + acc[kout * bb + j];
                                }
                            }
                            b0 += bb;
                        }
                    }
                }
                std::hint::black_box(&out);
            },
            2,
            timing_reps,
        )
        .median_s
    };
    let einsum_exact = time_einsum(Some(MathTier::Exact));
    let einsum_fast = time_einsum(Some(MathTier::Fast));
    let einsum_skel = time_einsum(None);
    record("einsum", einsum_exact, einsum_fast, einsum_skel);

    // --- mix: the vectorized mixing layer (running max, C exp sweeps +
    // weighted accumulate, ln finalize) over n = B·Ko values, C children
    let c_children = 4usize;
    let n = batch * ko;
    let kids: Vec<Vec<f32>> = (0..c_children)
        .map(|_| (0..n).map(|_| rng.uniform_in(-8.0, 0.0) as f32).collect())
        .collect();
    let wc: Vec<f32> = (0..c_children)
        .map(|_| rng.uniform_in(0.05, 1.0) as f32)
        .collect();
    let mut m = vec![0.0f32; n];
    let mut e = vec![0.0f32; n];
    let mut dst = vec![0.0f32; n];
    let mut time_mix = |math: Option<MathTier>| -> f64 {
        time_it(
            || {
                m.copy_from_slice(&kids[0]);
                for kid in &kids[1..] {
                    kernels::vmax_inplace(isa, &mut m, kid);
                }
                dst.fill(0.0);
                for (ci, kid) in kids.iter().enumerate() {
                    for ((ev, &sv), &mv) in e.iter_mut().zip(kid).zip(m.iter()) {
                        *ev = sv - mv;
                    }
                    if let Some(mt) = math {
                        kernels::vexp(isa, mt, &mut e);
                    }
                    kernels::axpy(isa, &mut dst, &e, wc[ci]);
                }
                if let Some(mt) = math {
                    kernels::vln(isa, mt, &mut dst);
                }
                for (dv, &mv) in dst.iter_mut().zip(m.iter()) {
                    *dv += mv;
                }
                std::hint::black_box(&dst);
            },
            2,
            timing_reps,
        )
        .median_s
    };
    let mix_exact = time_mix(Some(MathTier::Exact));
    let mix_fast = time_mix(Some(MathTier::Fast));
    let mix_skel = time_mix(None);
    record("mix", mix_exact, mix_fast, mix_skel);

    // --- leaf: the categorical log-normalizer loop (S exps + 1 ln per
    // component, scalar calls — the shape of `log_norm_const_tier` /
    // `emit_table_tier`) over D·K·R components
    let s_cats = 10usize;
    let n_comp = 256 * k; // D=256 vars, R=1
    let theta: Vec<f32> = (0..n_comp * s_cats)
        .map(|_| rng.uniform_in(-3.0, 3.0) as f32)
        .collect();
    let mut lnz = vec![0.0f32; n_comp];
    let mut time_leaf = |math: Option<MathTier>| -> f64 {
        time_it(
            || {
                for (ci, o) in lnz.iter_mut().enumerate() {
                    let row = &theta[ci * s_cats..(ci + 1) * s_cats];
                    let mut mx = f32::NEG_INFINITY;
                    for &t in row {
                        mx = mx.max(t);
                    }
                    match math {
                        Some(mt) => {
                            let mut z = 0.0f32;
                            for &t in row {
                                z += mt.exp1(t - mx);
                            }
                            *o = mx + mt.ln1(z);
                        }
                        None => {
                            let mut z = 0.0f32;
                            for &t in row {
                                z += t - mx;
                            }
                            *o = mx + z;
                        }
                    }
                }
                std::hint::black_box(&lnz);
            },
            2,
            timing_reps,
        )
        .median_s
    };
    let leaf_exact = time_leaf(Some(MathTier::Exact));
    let leaf_fast = time_leaf(Some(MathTier::Fast));
    let leaf_skel = time_leaf(None);
    record("leaf", leaf_exact, leaf_fast, leaf_skel);

    println!("\n{}", table.render());
}

fn main() {
    let quick = std::env::var("EINET_BENCH_QUICK").is_ok();
    let mut op_rows: Vec<json::Json> = Vec::new();
    let mut kernel_rows: Vec<json::Json> = Vec::new();
    let mut transc_rows: Vec<json::Json> = Vec::new();
    part1_dense_vs_sparse(quick, &mut op_rows);
    part2_kernel_sweep(quick, &mut kernel_rows);
    part3_transcendental_split(quick, &mut transc_rows);
    let report = json::obj(vec![
        ("experiment", json::s("einsum_kernels")),
        ("quick", json::num(quick as i32 as f64)),
        ("isa", json::s(Isa::best().name())),
        ("tier_default", json::s(MathTier::detect().name())),
        ("b_blk_policy", json::s("autotuned per (K, ISA); see kernel_rows[].b_blk")),
        ("op_rows", json::arr(op_rows)),
        ("kernel_rows", json::arr(kernel_rows)),
        ("transc_rows", json::arr(transc_rows)),
    ]);
    std::fs::write("BENCH_kernels.json", report.to_string())
        .expect("write BENCH_kernels.json");
    println!("wrote BENCH_kernels.json");
}
