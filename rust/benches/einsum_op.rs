//! Section 3.2 micro-benchmark: the basic einsum operation in isolation,
//! plus the kernel-layout sweep behind `BENCH_kernels.json`.
//!
//! Part 1 (the paper's op-count analysis): for one vectorized sum-product
//! with children of length K,
//!   dense  (Eq. 4): O(K^3) mul-adds, 2K exp, K log, NO product storage
//!   sparse (LibSPN/SPFlow style): O(K^3) adds, K^3 exp, K log, K^2 stored
//! This isolates exactly that unit over a K sweep to show where the
//! crossover in exp-ops vs mul-adds lands on CPU.
//!
//! Part 2 (the systems sweep): the SAME dense einsum step at batch
//! B = 256, three ways —
//!   per-row scalar   : row-major product + per-row `dot4`/`max4`
//!                      (the pre-kernel engine path: the weight slot is
//!                      re-streamed once per batch row)
//!   blocked scalar   : transposed [K², b_blk] operand + the portable
//!                      4-lane-chunked `einsum_block`
//!   blocked SIMD     : the same blocked kernel on the detected ISA
//!                      (AVX2 / NEON)
//! All three start from the same scaled-exponential children (the 2K exps
//! and K logs per row are identical across layouts and included in every
//! timing), and all three are asserted bit-identical before timing.
//! Results go to stdout and BENCH_kernels.json (schema documented in
//! docs/BENCHMARKS.md).
//!
//!     cargo bench --bench einsum_op
//!     EINET_BENCH_QUICK=1 cargo bench --bench einsum_op   # CI quick mode

use einet::bench::{fmt_si, time_it, Table};
use einet::engine::exec::Semiring;
use einet::engine::kernels::{self, Isa};
use einet::util::json;
use einet::util::rng::Rng;

/// dense: log-einsum-exp (Eq. 4)
fn dense_op(logn: &[f32], lognp: &[f32], w: &[f32], k: usize, out: &mut [f32]) {
    let mut a = f32::NEG_INFINITY;
    let mut ap = f32::NEG_INFINITY;
    for i in 0..k {
        a = a.max(logn[i]);
        ap = ap.max(lognp[i]);
    }
    // en/enp in stack buffers
    let mut en = vec![0.0f32; k];
    let mut enp = vec![0.0f32; k];
    for i in 0..k {
        en[i] = (logn[i] - a).exp();
        enp[i] = (lognp[i] - ap).exp();
    }
    for ko in 0..k {
        let wrow = &w[ko * k * k..(ko + 1) * k * k];
        let mut acc = 0.0f32;
        for i in 0..k {
            let eni = en[i];
            let wr = &wrow[i * k..(i + 1) * k];
            let mut dot = 0.0f32;
            for j in 0..k {
                dot += wr[j] * enp[j];
            }
            acc += eni * dot;
        }
        out[ko] = a + ap + acc.ln();
    }
}

/// sparse: explicit outer-sum product + broadcast logw + K^2 logsumexp
fn sparse_op(
    logn: &[f32],
    lognp: &[f32],
    logw: &[f32],
    k: usize,
    prod: &mut [f32],
    out: &mut [f32],
) {
    for i in 0..k {
        for j in 0..k {
            prod[i * k + j] = logn[i] + lognp[j];
        }
    }
    for ko in 0..k {
        let wrow = &logw[ko * k * k..(ko + 1) * k * k];
        let mut m = f32::NEG_INFINITY;
        for idx in 0..k * k {
            m = m.max(wrow[idx] + prod[idx]);
        }
        let mut s = 0.0f32;
        for idx in 0..k * k {
            s += (wrow[idx] + prod[idx] - m).exp();
        }
        out[ko] = m + s.ln();
    }
}

/// One full einsum step over the batch, per-row layout: per row compute
/// the scaled children, the row-major K² product, then Ko `dot4`/`max4`
/// reductions + logs — exactly what the engines did before the blocked
/// kernels.
#[allow(clippy::too_many_arguments)]
fn step_per_row(
    sr: Semiring,
    logn: &[f32],
    lognp: &[f32],
    w: &[f32],
    k: usize,
    ko: usize,
    bn: usize,
    en: &mut [f32],
    enp: &mut [f32],
    prod: &mut [f32],
    out: &mut [f32],
) {
    let k2 = k * k;
    for b in 0..bn {
        let lrow = &logn[b * k..(b + 1) * k];
        let rrow = &lognp[b * k..(b + 1) * k];
        let mut a = f32::NEG_INFINITY;
        let mut ap = f32::NEG_INFINITY;
        for kk in 0..k {
            a = a.max(lrow[kk]);
            ap = ap.max(rrow[kk]);
        }
        for kk in 0..k {
            en[kk] = (lrow[kk] - a).exp();
            enp[kk] = (rrow[kk] - ap).exp();
        }
        for (ii, &eni) in en.iter().enumerate() {
            for (p, &enpj) in prod[ii * k..(ii + 1) * k].iter_mut().zip(enp.iter()) {
                *p = eni * enpj;
            }
        }
        let base = a + ap;
        for kout in 0..ko {
            let wrow = &w[kout * k2..(kout + 1) * k2];
            let acc = match sr {
                Semiring::SumProduct => kernels::dot4(Isa::Scalar, wrow, prod),
                Semiring::MaxProduct => kernels::max4(Isa::Scalar, wrow, prod),
            };
            out[b * ko + kout] = base + acc.ln();
        }
    }
}

/// The same step through the blocked kernels under `isa`: per block of
/// `b_blk` rows build the transposed operands and run `outer_block` +
/// `einsum_block`, then add the row maxima back.
#[allow(clippy::too_many_arguments)]
fn step_blocked(
    isa: Isa,
    sr: Semiring,
    logn: &[f32],
    lognp: &[f32],
    w: &[f32],
    k: usize,
    ko: usize,
    bn: usize,
    b_blk: usize,
    en_t: &mut [f32],
    enp_t: &mut [f32],
    prod_t: &mut [f32],
    acc: &mut [f32],
    base: &mut [f32],
    out: &mut [f32],
) {
    let k2 = k * k;
    let mut b0 = 0usize;
    while b0 < bn {
        let bb = b_blk.min(bn - b0);
        for j in 0..bb {
            let b = b0 + j;
            let lrow = &logn[b * k..(b + 1) * k];
            let rrow = &lognp[b * k..(b + 1) * k];
            let mut a = f32::NEG_INFINITY;
            let mut ap = f32::NEG_INFINITY;
            for kk in 0..k {
                a = a.max(lrow[kk]);
                ap = ap.max(rrow[kk]);
            }
            base[j] = a + ap;
            for kk in 0..k {
                en_t[kk * bb + j] = (lrow[kk] - a).exp();
                enp_t[kk * bb + j] = (rrow[kk] - ap).exp();
            }
        }
        kernels::outer_block(isa, en_t, enp_t, k, bb, prod_t);
        kernels::einsum_block(isa, sr, w, prod_t, k2, ko, bb, acc);
        for j in 0..bb {
            for kout in 0..ko {
                out[(b0 + j) * ko + kout] = base[j] + acc[kout * bb + j].ln();
            }
        }
        b0 += bb;
    }
}

fn part1_dense_vs_sparse(quick: bool, report_rows: &mut Vec<json::Json>) {
    let mut rng = Rng::new(0);
    println!("Section 3.2 — basic einsum op, dense (Eq. 4) vs sparse workaround");
    let mut table = Table::new(&["K", "dense", "sparse", "speedup", "max |diff|"]);
    let ks: &[usize] = if quick { &[4, 8, 16] } else { &[2, 4, 8, 16, 32, 64] };
    for &k in ks {
        let logn: Vec<f32> = (0..k).map(|_| rng.normal() as f32 - 2.0).collect();
        let lognp: Vec<f32> = (0..k).map(|_| rng.normal() as f32 - 2.0).collect();
        let mut w: Vec<f32> = (0..k * k * k)
            .map(|_| rng.uniform_in(0.01, 1.0) as f32)
            .collect();
        for block in w.chunks_mut(k * k) {
            let total: f32 = block.iter().sum();
            for v in block.iter_mut() {
                *v /= total;
            }
        }
        let logw: Vec<f32> = w.iter().map(|&v| v.ln()).collect();
        let mut out_d = vec![0.0f32; k];
        let mut out_s = vec![0.0f32; k];
        let mut prod = vec![0.0f32; k * k];
        let reps = 512.max(65536 / (k * k));
        let timing_reps = if quick { 3 } else { 5 };
        let md = time_it(
            || {
                for _ in 0..reps {
                    dense_op(&logn, &lognp, &w, k, &mut out_d);
                    std::hint::black_box(&out_d);
                }
            },
            1,
            timing_reps,
        );
        let ms = time_it(
            || {
                for _ in 0..reps {
                    sparse_op(&logn, &lognp, &logw, k, &mut prod, &mut out_s);
                    std::hint::black_box(&out_s);
                }
            },
            1,
            timing_reps,
        );
        let diff = out_d
            .iter()
            .zip(&out_s)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        table.row(vec![
            format!("{k}"),
            fmt_si(md.median_s / reps as f64),
            fmt_si(ms.median_s / reps as f64),
            format!("{:.1}x", ms.median_s / md.median_s),
            format!("{diff:.2e}"),
        ]);
        println!(
            "K={k:<3} dense {}  sparse {}  speedup {:.1}x  diff {diff:.1e}",
            fmt_si(md.median_s / reps as f64),
            fmt_si(ms.median_s / reps as f64),
            ms.median_s / md.median_s
        );
        assert!(diff < 1e-3, "layouts disagree");
        report_rows.push(json::obj(vec![
            ("k", json::num(k as f64)),
            ("dense_op_s", json::num(md.median_s / reps as f64)),
            ("sparse_op_s", json::num(ms.median_s / reps as f64)),
            ("sparse_vs_dense", json::num(ms.median_s / md.median_s)),
        ]));
    }
    println!("\n{}", table.render());
}

/// Kernel-only, per-row layout: from precomputed scaled children, build
/// each row's K² product and run Ko `dot4`/`max4` reductions — the
/// contraction exactly as the pre-kernel engines executed it (linear
/// domain; the identical exp/ln plumbing around it is timed separately
/// in the `step_*` figures).
#[allow(clippy::too_many_arguments)]
fn kernel_per_row(
    sr: Semiring,
    en_all: &[f32],
    enp_all: &[f32],
    w: &[f32],
    k: usize,
    ko: usize,
    bn: usize,
    prod: &mut [f32],
    out: &mut [f32],
) {
    let k2 = k * k;
    for b in 0..bn {
        let en = &en_all[b * k..(b + 1) * k];
        let enp = &enp_all[b * k..(b + 1) * k];
        for (ii, &eni) in en.iter().enumerate() {
            for (p, &enpj) in prod[ii * k..(ii + 1) * k].iter_mut().zip(enp.iter()) {
                *p = eni * enpj;
            }
        }
        for kout in 0..ko {
            let wrow = &w[kout * k2..(kout + 1) * k2];
            out[b * ko + kout] = match sr {
                Semiring::SumProduct => kernels::dot4(Isa::Scalar, wrow, prod),
                Semiring::MaxProduct => kernels::max4(Isa::Scalar, wrow, prod),
            };
        }
    }
}

/// Kernel-only, blocked layout: `outer_block` + `einsum_block` per
/// 16-row block over block-transposed children (`[nblocks, k, b_blk]`).
#[allow(clippy::too_many_arguments)]
fn kernel_blocked(
    isa: Isa,
    sr: Semiring,
    en_t_all: &[f32],
    enp_t_all: &[f32],
    w: &[f32],
    k: usize,
    ko: usize,
    bn: usize,
    b_blk: usize,
    prod_t: &mut [f32],
    acc: &mut [f32],
    out: &mut [f32],
) {
    let k2 = k * k;
    let mut b0 = 0usize;
    while b0 < bn {
        let bb = b_blk.min(bn - b0);
        let blk = (b0 / b_blk) * k * b_blk;
        kernels::outer_block(
            isa,
            &en_t_all[blk..blk + k * bb],
            &enp_t_all[blk..blk + k * bb],
            k,
            bb,
            prod_t,
        );
        kernels::einsum_block(isa, sr, w, prod_t, k2, ko, bb, acc);
        for j in 0..bb {
            for kout in 0..ko {
                out[(b0 + j) * ko + kout] = acc[kout * bb + j];
            }
        }
        b0 += bb;
    }
}

fn sr_tag(sr: Semiring) -> &'static str {
    match sr {
        Semiring::SumProduct => "sum",
        Semiring::MaxProduct => "max",
    }
}

fn part2_kernel_sweep(quick: bool, report_rows: &mut Vec<json::Json>) {
    let isa = Isa::best();
    let batch = 256usize;
    let b_blk = kernels::block_rows(batch);
    println!(
        "Kernel sweep — per-row scalar vs blocked scalar vs blocked {} (B={batch}, b_blk={b_blk})",
        isa.name()
    );
    let mut table = Table::new(&[
        "K",
        "semiring",
        "kernel/row",
        "kernel/blocked",
        "kernel/simd",
        "simd vs row",
        "full step",
    ]);
    let ks: &[usize] = if quick { &[4, 8, 16] } else { &[2, 4, 8, 10, 16, 32] };
    for &k in ks {
        let ko = k;
        let k2 = k * k;
        let mut rng = Rng::new(7 + k as u64);
        let logn: Vec<f32> = (0..batch * k)
            .map(|_| rng.uniform_in(-8.0, 0.0) as f32)
            .collect();
        let lognp: Vec<f32> = (0..batch * k)
            .map(|_| rng.uniform_in(-8.0, 0.0) as f32)
            .collect();
        let mut w: Vec<f32> = (0..ko * k2)
            .map(|_| rng.uniform_in(0.01, 1.0) as f32)
            .collect();
        for block in w.chunks_mut(k2) {
            let total: f32 = block.iter().sum();
            for v in block.iter_mut() {
                *v /= total;
            }
        }
        // precompute scaled children once, in both layouts (row-major and
        // block-transposed) — they are byte-for-byte the same values
        let mut en_all = vec![0.0f32; batch * k];
        let mut enp_all = vec![0.0f32; batch * k];
        for b in 0..batch {
            let lrow = &logn[b * k..(b + 1) * k];
            let rrow = &lognp[b * k..(b + 1) * k];
            let mut a = f32::NEG_INFINITY;
            let mut ap = f32::NEG_INFINITY;
            for kk in 0..k {
                a = a.max(lrow[kk]);
                ap = ap.max(rrow[kk]);
            }
            for kk in 0..k {
                en_all[b * k + kk] = (lrow[kk] - a).exp();
                enp_all[b * k + kk] = (rrow[kk] - ap).exp();
            }
        }
        let mut en_t_all = vec![0.0f32; batch * k];
        let mut enp_t_all = vec![0.0f32; batch * k];
        for b in 0..batch {
            let (bi, j) = (b / b_blk, b % b_blk);
            for kk in 0..k {
                en_t_all[bi * k * b_blk + kk * b_blk + j] = en_all[b * k + kk];
                enp_t_all[bi * k * b_blk + kk * b_blk + j] = enp_all[b * k + kk];
            }
        }
        let mut prod = vec![0.0f32; k2];
        let mut en = vec![0.0f32; k];
        let mut enp = vec![0.0f32; k];
        let mut prod_t = vec![0.0f32; k2 * b_blk];
        let mut acc = vec![0.0f32; ko * b_blk];
        let mut base = vec![0.0f32; b_blk];
        let mut out_row = vec![0.0f32; batch * ko];
        let mut out_blk = vec![0.0f32; batch * ko];
        let mut out_simd = vec![0.0f32; batch * ko];
        let timing_reps = if quick { 5 } else { 9 };
        let mut row = vec![
            ("k", json::num(k as f64)),
            ("ko", json::num(ko as f64)),
            ("batch", json::num(batch as f64)),
            ("b_blk", json::num(b_blk as f64)),
        ];
        for sr in [Semiring::SumProduct, Semiring::MaxProduct] {
            // correctness first: all three contraction paths bit-identical
            kernel_per_row(sr, &en_all, &enp_all, &w, k, ko, batch, &mut prod, &mut out_row);
            kernel_blocked(
                Isa::Scalar, sr, &en_t_all, &enp_t_all, &w, k, ko, batch, b_blk,
                &mut prod_t, &mut acc, &mut out_blk,
            );
            kernel_blocked(
                isa, sr, &en_t_all, &enp_t_all, &w, k, ko, batch, b_blk,
                &mut prod_t, &mut acc, &mut out_simd,
            );
            for i in 0..batch * ko {
                assert_eq!(
                    out_row[i].to_bits(),
                    out_blk[i].to_bits(),
                    "per-row vs blocked diverge at K={k} {sr:?} [{i}]"
                );
                assert_eq!(
                    out_blk[i].to_bits(),
                    out_simd[i].to_bits(),
                    "blocked scalar vs SIMD diverge at K={k} {sr:?} [{i}]"
                );
            }
            // ... and so are the full steps (exp prep + contraction + ln)
            let mut en_t = vec![0.0f32; k * b_blk];
            let mut enp_t = vec![0.0f32; k * b_blk];
            step_per_row(
                sr, &logn, &lognp, &w, k, ko, batch, &mut en, &mut enp, &mut prod,
                &mut out_row,
            );
            step_blocked(
                isa, sr, &logn, &lognp, &w, k, ko, batch, b_blk,
                &mut en_t, &mut enp_t, &mut prod_t, &mut acc, &mut base, &mut out_simd,
            );
            for i in 0..batch * ko {
                assert_eq!(
                    out_row[i].to_bits(),
                    out_simd[i].to_bits(),
                    "full step diverges at K={k} {sr:?} [{i}]"
                );
            }
            // kernel-only timings (the headline: the contraction itself)
            let t_row = time_it(
                || {
                    kernel_per_row(
                        sr, &en_all, &enp_all, &w, k, ko, batch, &mut prod, &mut out_row,
                    );
                    std::hint::black_box(&out_row);
                },
                2,
                timing_reps,
            );
            let t_blk = time_it(
                || {
                    kernel_blocked(
                        Isa::Scalar, sr, &en_t_all, &enp_t_all, &w, k, ko, batch, b_blk,
                        &mut prod_t, &mut acc, &mut out_blk,
                    );
                    std::hint::black_box(&out_blk);
                },
                2,
                timing_reps,
            );
            let t_simd = time_it(
                || {
                    kernel_blocked(
                        isa, sr, &en_t_all, &enp_t_all, &w, k, ko, batch, b_blk,
                        &mut prod_t, &mut acc, &mut out_simd,
                    );
                    std::hint::black_box(&out_simd);
                },
                2,
                timing_reps,
            );
            // full-step timings (exp prep + contraction + ln): what the
            // engine-level forward pays, transcendentals included
            let t_step_row = time_it(
                || {
                    step_per_row(
                        sr, &logn, &lognp, &w, k, ko, batch, &mut en, &mut enp, &mut prod,
                        &mut out_row,
                    );
                    std::hint::black_box(&out_row);
                },
                2,
                timing_reps,
            );
            let t_step_simd = time_it(
                || {
                    step_blocked(
                        isa, sr, &logn, &lognp, &w, k, ko, batch, b_blk,
                        &mut en_t, &mut enp_t, &mut prod_t, &mut acc, &mut base, &mut out_simd,
                    );
                    std::hint::black_box(&out_simd);
                },
                2,
                timing_reps,
            );
            let simd_vs_row = t_row.median_s / t_simd.median_s;
            let step_ratio = t_step_row.median_s / t_step_simd.median_s;
            let tag = sr_tag(sr);
            table.row(vec![
                format!("{k}"),
                tag.into(),
                fmt_si(t_row.median_s),
                fmt_si(t_blk.median_s),
                fmt_si(t_simd.median_s),
                format!("{simd_vs_row:.2}x"),
                format!("{step_ratio:.2}x"),
            ]);
            println!(
                "K={k:<3} {tag}: kernel row {} blocked {} {} {} ({simd_vs_row:.2}x); full step {} -> {} ({step_ratio:.2}x)",
                fmt_si(t_row.median_s),
                fmt_si(t_blk.median_s),
                isa.name(),
                fmt_si(t_simd.median_s),
                fmt_si(t_step_row.median_s),
                fmt_si(t_step_simd.median_s),
            );
            let key = |name: &'static str, alt: &'static str| -> &'static str {
                match sr {
                    Semiring::SumProduct => name,
                    Semiring::MaxProduct => alt,
                }
            };
            row.push((key("kernel_row_sum_s", "kernel_row_max_s"), json::num(t_row.median_s)));
            row.push((
                key("kernel_blocked_sum_s", "kernel_blocked_max_s"),
                json::num(t_blk.median_s),
            ));
            row.push((
                key("kernel_simd_sum_s", "kernel_simd_max_s"),
                json::num(t_simd.median_s),
            ));
            row.push((key("simd_vs_row_sum", "simd_vs_row_max"), json::num(simd_vs_row)));
            row.push((
                key("step_row_sum_s", "step_row_max_s"),
                json::num(t_step_row.median_s),
            ));
            row.push((
                key("step_simd_sum_s", "step_simd_max_s"),
                json::num(t_step_simd.median_s),
            ));
            row.push((
                key("step_simd_vs_row_sum", "step_simd_vs_row_max"),
                json::num(step_ratio),
            ));
        }
        report_rows.push(json::obj(row));
    }
    println!("\n{}", table.render());
}

fn main() {
    let quick = std::env::var("EINET_BENCH_QUICK").is_ok();
    let mut op_rows: Vec<json::Json> = Vec::new();
    let mut kernel_rows: Vec<json::Json> = Vec::new();
    part1_dense_vs_sparse(quick, &mut op_rows);
    part2_kernel_sweep(quick, &mut kernel_rows);
    let report = json::obj(vec![
        ("experiment", json::s("einsum_kernels")),
        ("quick", json::num(quick as i32 as f64)),
        ("isa", json::s(Isa::best().name())),
        ("b_blk", json::num(kernels::block_rows(256) as f64)),
        ("op_rows", json::arr(op_rows)),
        ("kernel_rows", json::arr(kernel_rows)),
    ]);
    std::fs::write("BENCH_kernels.json", report.to_string())
        .expect("write BENCH_kernels.json");
    println!("wrote BENCH_kernels.json");
}
