//! Section 3.2 micro-benchmark: the basic einsum operation in isolation.
//!
//! The paper's op-count analysis: for one vectorized sum-product with
//! children of length K,
//!   dense  (Eq. 4): O(K^3) mul-adds, 2K exp, K log, NO product storage
//!   sparse (LibSPN/SPFlow style): O(K^3) adds, K^3 exp, K log, K^2 stored
//! This bench isolates exactly that unit over a K sweep to show where the
//! crossover in exp-ops vs mul-adds lands on CPU.
//!
//!     cargo bench --bench einsum_op

use einet::bench::{fmt_si, time_it, Table};
use einet::util::rng::Rng;

/// dense: log-einsum-exp (Eq. 4)
fn dense_op(logn: &[f32], lognp: &[f32], w: &[f32], k: usize, out: &mut [f32]) {
    let mut a = f32::NEG_INFINITY;
    let mut ap = f32::NEG_INFINITY;
    for i in 0..k {
        a = a.max(logn[i]);
        ap = ap.max(lognp[i]);
    }
    // en/enp in stack buffers
    let mut en = vec![0.0f32; k];
    let mut enp = vec![0.0f32; k];
    for i in 0..k {
        en[i] = (logn[i] - a).exp();
        enp[i] = (lognp[i] - ap).exp();
    }
    for ko in 0..k {
        let wrow = &w[ko * k * k..(ko + 1) * k * k];
        let mut acc = 0.0f32;
        for i in 0..k {
            let eni = en[i];
            let wr = &wrow[i * k..(i + 1) * k];
            let mut dot = 0.0f32;
            for j in 0..k {
                dot += wr[j] * enp[j];
            }
            acc += eni * dot;
        }
        out[ko] = a + ap + acc.ln();
    }
}

/// sparse: explicit outer-sum product + broadcast logw + K^2 logsumexp
fn sparse_op(
    logn: &[f32],
    lognp: &[f32],
    logw: &[f32],
    k: usize,
    prod: &mut [f32],
    out: &mut [f32],
) {
    for i in 0..k {
        for j in 0..k {
            prod[i * k + j] = logn[i] + lognp[j];
        }
    }
    for ko in 0..k {
        let wrow = &logw[ko * k * k..(ko + 1) * k * k];
        let mut m = f32::NEG_INFINITY;
        for idx in 0..k * k {
            m = m.max(wrow[idx] + prod[idx]);
        }
        let mut s = 0.0f32;
        for idx in 0..k * k {
            s += (wrow[idx] + prod[idx] - m).exp();
        }
        out[ko] = m + s.ln();
    }
}

fn main() {
    let mut rng = Rng::new(0);
    println!("Section 3.2 — basic einsum op, dense (Eq. 4) vs sparse workaround");
    let mut table = Table::new(&["K", "dense", "sparse", "speedup", "max |diff|"]);
    for k in [2usize, 4, 8, 16, 32, 64] {
        let logn: Vec<f32> = (0..k).map(|_| rng.normal() as f32 - 2.0).collect();
        let lognp: Vec<f32> = (0..k).map(|_| rng.normal() as f32 - 2.0).collect();
        let mut w: Vec<f32> = (0..k * k * k)
            .map(|_| rng.uniform_in(0.01, 1.0) as f32)
            .collect();
        for block in w.chunks_mut(k * k) {
            let total: f32 = block.iter().sum();
            for v in block.iter_mut() {
                *v /= total;
            }
        }
        let logw: Vec<f32> = w.iter().map(|&v| v.ln()).collect();
        let mut out_d = vec![0.0f32; k];
        let mut out_s = vec![0.0f32; k];
        let mut prod = vec![0.0f32; k * k];
        let reps = 512.max(65536 / (k * k));
        let md = time_it(
            || {
                for _ in 0..reps {
                    dense_op(&logn, &lognp, &w, k, &mut out_d);
                    std::hint::black_box(&out_d);
                }
            },
            1,
            5,
        );
        let ms = time_it(
            || {
                for _ in 0..reps {
                    sparse_op(&logn, &lognp, &logw, k, &mut prod, &mut out_s);
                    std::hint::black_box(&out_s);
                }
            },
            1,
            5,
        );
        let diff = out_d
            .iter()
            .zip(&out_s)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        table.row(vec![
            format!("{k}"),
            fmt_si(md.median_s / reps as f64),
            fmt_si(ms.median_s / reps as f64),
            format!("{:.1}x", ms.median_s / md.median_s),
            format!("{diff:.2e}"),
        ]);
        println!(
            "K={k:<3} dense {}  sparse {}  speedup {:.1}x  diff {diff:.1e}",
            fmt_si(md.median_s / reps as f64),
            fmt_si(ms.median_s / reps as f64),
            ms.median_s / md.median_s
        );
        assert!(diff < 1e-3, "layouts disagree");
    }
    println!("\n{}", table.render());
}
