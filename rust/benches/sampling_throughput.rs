//! Batched vs per-sample top-down sampling throughput.
//!
//! Compares the legacy path (one 1-row forward + B per-sample region-graph
//! walks, `Engine::sample`) against the fused path (one 1-row forward +
//! ONE batched `SamplePlan` execution, `Engine::sample_batch`) at B = 256
//! on both engines, plus a batched conditional-decode measurement for the
//! serving workload. Results go to stdout and BENCH_sampling.json.
//!
//!     cargo bench --bench sampling_throughput
//!     EINET_BENCH_QUICK=1 cargo bench --bench sampling_throughput

use einet::bench::{fmt_si, time_it, Table};
use einet::util::json;
use einet::util::rng::Rng;
use einet::{
    DecodeMode, DenseEngine, EinetParams, Engine, LayeredPlan, LeafFamily,
    SparseEngine,
};

struct Row {
    engine: &'static str,
    batch: usize,
    per_sample_s: f64,
    batched_s: f64,
    cond_batched_s: f64,
}

fn bench_engine<E: Engine>(
    name: &'static str,
    plan: &LayeredPlan,
    batch: usize,
    repeats: usize,
) -> Row {
    let family = LeafFamily::Bernoulli;
    let params = EinetParams::init(plan, family, 0);
    let mut engine = E::build(plan.clone(), family, batch);
    let nv = plan.graph.num_vars;

    // legacy: forward once (bn = 1), then `batch` stack walks
    let mut rng = Rng::new(1);
    let legacy = time_it(
        || {
            let out = Engine::sample(&mut engine, &params, batch, &mut rng, DecodeMode::Sample);
            std::hint::black_box(out.len());
        },
        1,
        repeats,
    );

    // batched: forward once (bn = 1), then ONE SamplePlan execution
    let mut rng = Rng::new(2);
    let batched = time_it(
        || {
            let out = engine.sample_batch(&params, batch, &mut rng, DecodeMode::Sample);
            std::hint::black_box(out.len());
        },
        1,
        repeats,
    );

    // conditional decode (inpainting/serving shape): batched forward over
    // real evidence + one batched decode
    let mut rng = Rng::new(3);
    let mut x = vec![0.0f32; batch * nv];
    for v in x.iter_mut() {
        *v = if rng.bernoulli(0.5) { 1.0 } else { 0.0 };
    }
    let mask: Vec<f32> = (0..nv).map(|d| if d % 2 == 0 { 1.0 } else { 0.0 }).collect();
    let mut logp = vec![0.0f32; batch];
    engine.forward(&params, &x, &mask, &mut logp);
    let mut out = x.clone();
    let cond = time_it(
        || {
            out.copy_from_slice(&x);
            engine.decode_batch(&params, batch, &mask, DecodeMode::Sample, &mut rng, &mut out);
            std::hint::black_box(out[0]);
        },
        1,
        repeats,
    );

    Row {
        engine: name,
        batch,
        per_sample_s: legacy.median_s,
        batched_s: batched.median_s,
        cond_batched_s: cond.median_s,
    }
}

fn main() {
    let quick = std::env::var("EINET_BENCH_QUICK").is_ok();
    let batch = 256usize;
    let repeats = if quick { 3 } else { 7 };

    // dense: a model whose weight arena dwarfs L2 so the per-sample walk
    // pays a cache miss per visited block; sparse: moderated so its
    // [B, K^2] product arena stays reasonable
    let (d_nv, d_k, d_depth, d_rep) = if quick { (64, 12, 5, 6) } else { (128, 16, 5, 8) };
    let (s_nv, s_k, s_depth, s_rep) = if quick { (48, 8, 4, 4) } else { (64, 10, 4, 5) };

    let dense_plan = LayeredPlan::compile(
        einet::structure::random_binary_trees(d_nv, d_depth, d_rep, 7),
        d_k,
    );
    let sparse_plan = LayeredPlan::compile(
        einet::structure::random_binary_trees(s_nv, s_depth, s_rep, 7),
        s_k,
    );

    println!("Sampling throughput — per-sample walk vs batched SamplePlan (B={batch})");
    let rows = vec![
        bench_engine::<DenseEngine>("dense", &dense_plan, batch, repeats),
        bench_engine::<SparseEngine>("sparse", &sparse_plan, batch, repeats),
    ];

    let mut table = Table::new(&[
        "engine",
        "per-sample (B walks)",
        "batched (1 plan)",
        "speedup",
        "batched samples/s",
        "cond decode/batch",
    ]);
    let mut report_rows: Vec<json::Json> = Vec::new();
    for r in &rows {
        let speedup = r.per_sample_s / r.batched_s;
        let sps = r.batch as f64 / r.batched_s;
        table.row(vec![
            r.engine.to_string(),
            fmt_si(r.per_sample_s),
            fmt_si(r.batched_s),
            format!("{speedup:.1}x"),
            format!("{sps:.0}"),
            fmt_si(r.cond_batched_s),
        ]);
        println!(
            "{:<7} per-sample {}  batched {}  speedup {:.1}x  ({:.0} samples/s batched)",
            r.engine,
            fmt_si(r.per_sample_s),
            fmt_si(r.batched_s),
            speedup,
            sps
        );
        report_rows.push(json::obj(vec![
            ("engine", json::s(r.engine)),
            ("batch", json::num(r.batch as f64)),
            ("per_sample_s", json::num(r.per_sample_s)),
            ("batched_s", json::num(r.batched_s)),
            ("speedup", json::num(speedup)),
            ("batched_samples_per_s", json::num(sps)),
            ("per_sample_samples_per_s", json::num(r.batch as f64 / r.per_sample_s)),
            ("cond_decode_batch_s", json::num(r.cond_batched_s)),
        ]));
    }
    println!("\n{}", table.render());
    let report = json::obj(vec![
        ("experiment", json::s("sampling_throughput")),
        ("quick", json::num(quick as i32 as f64)),
        ("batch", json::num(batch as f64)),
        ("rows", json::arr(report_rows)),
    ]);
    std::fs::write("BENCH_sampling.json", report.to_string())
        .expect("write BENCH_sampling.json");
    println!("wrote BENCH_sampling.json");
}
