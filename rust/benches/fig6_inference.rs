//! Fig. 6 reproduction (supplementary): inference time per sample +
//! memory, same K / depth / replica sweep as Fig. 3, forward pass only on
//! a batch of 100 test samples (the paper reports time/100-batch / 100).
//!
//!     cargo bench --bench fig6_inference
//!     EINET_BENCH_QUICK=1 cargo bench --bench fig6_inference

use einet::bench::{fmt_bytes, fmt_si, time_it, Table};
use einet::data::debd::gaussian_noise;
use einet::{DenseEngine, EinetParams, LayeredPlan, LeafFamily, SparseEngine};

fn main() {
    let quick = std::env::var("EINET_BENCH_QUICK").is_ok();
    let num_vars = if quick { 128 } else { 512 };
    let batch = 100usize;
    let data = gaussian_noise(batch, num_vars, 1);
    let family = LeafFamily::Gaussian { channels: 1 };
    let mask = vec![1.0f32; num_vars];

    let kk: &[usize] = if quick { &[2, 8] } else { &[1, 2, 4, 8, 16, 32] };
    let dd: &[usize] = if quick { &[2, 4] } else { &[1, 2, 3, 4, 5, 6] };
    let rr: &[usize] = if quick { &[2, 8] } else { &[1, 2, 5, 10, 20] };
    let mut points = Vec::new();
    for &k in kk {
        points.push((format!("K={k}"), k, 4usize, 10usize));
    }
    for &d in dd {
        points.push((format!("D={d}"), 10, d, 10));
    }
    for &r in rr {
        points.push((format!("R={r}"), 10, 4, r));
    }

    println!("Fig. 6 — inference time/sample (batch {batch}), D={num_vars} Gaussian noise");
    let mut table = Table::new(&[
        "point", "dense t/sample", "sparse t/sample", "speedup",
        "dense mem", "sparse mem",
    ]);
    for (label, k, depth, replica) in points {
        let graph =
            einet::structure::random_binary_trees(num_vars, depth, replica, 7);
        let plan = LayeredPlan::compile(graph, k);
        let params = EinetParams::init(&plan, family, 0);
        let mut dense = DenseEngine::new(plan.clone(), family, batch);
        let mut sparse = SparseEngine::new(plan.clone(), family, batch);
        let mut logp = vec![0.0f32; batch];
        let md = time_it(
            || dense.forward(&params, &data.data, &mask, &mut logp),
            1,
            if quick { 3 } else { 5 },
        );
        let ms = time_it(
            || sparse.forward(&params, &data.data, &mask, &mut logp),
            1,
            if quick { 3 } else { 5 },
        );
        let mem_d = dense.memory_footprint(&params).total();
        let mem_s = sparse.memory_footprint(&params).total();
        table.row(vec![
            label.clone(),
            fmt_si(md.median_s / batch as f64),
            fmt_si(ms.median_s / batch as f64),
            format!("{:.1}x", ms.median_s / md.median_s),
            fmt_bytes(mem_d),
            fmt_bytes(mem_s),
        ]);
        println!(
            "{:<6} dense {}/sample  sparse {}/sample  speedup {:.1}x",
            label,
            fmt_si(md.median_s / batch as f64),
            fmt_si(ms.median_s / batch as f64),
            ms.median_s / md.median_s
        );
    }
    println!("\n{}", table.render());
}
