//! Fig. 6 reproduction (supplementary): inference time per sample +
//! memory, same K / depth / replica sweep as Fig. 3, forward pass only on
//! a batch of 100 test samples (the paper reports time/100-batch / 100).
//! Both engines run through the shared `Engine` trait; results are also
//! recorded in BENCH_fig6.json.
//!
//!     cargo bench --bench fig6_inference
//!     EINET_BENCH_QUICK=1 cargo bench --bench fig6_inference

use einet::bench::{fmt_bytes, fmt_si, time_it, Table};
use einet::data::debd::gaussian_noise;
use einet::util::json;
use einet::{
    DenseEngine, EinetParams, Engine, LayeredPlan, LeafFamily, SparseEngine,
};

/// One timed forward measurement through the trait — the same code path
/// either engine serves from.
fn time_forward<E: Engine>(
    engine: &mut E,
    params: &EinetParams,
    x: &[f32],
    mask: &[f32],
    batch: usize,
    repeats: usize,
) -> f64 {
    let mut logp = vec![0.0f32; batch];
    time_it(|| engine.forward(params, x, mask, &mut logp), 1, repeats).median_s
}

fn main() {
    let quick = std::env::var("EINET_BENCH_QUICK").is_ok();
    let num_vars = if quick { 128 } else { 512 };
    let batch = 100usize;
    let data = gaussian_noise(batch, num_vars, 1);
    let family = LeafFamily::Gaussian { channels: 1 };
    let mask = vec![1.0f32; num_vars];
    let repeats = if quick { 3 } else { 5 };

    let kk: &[usize] = if quick { &[2, 8] } else { &[1, 2, 4, 8, 16, 32] };
    let dd: &[usize] = if quick { &[2, 4] } else { &[1, 2, 3, 4, 5, 6] };
    let rr: &[usize] = if quick { &[2, 8] } else { &[1, 2, 5, 10, 20] };
    let mut points = Vec::new();
    for &k in kk {
        points.push((format!("K={k}"), k, 4usize, 10usize));
    }
    for &d in dd {
        points.push((format!("D={d}"), 10, d, 10));
    }
    for &r in rr {
        points.push((format!("R={r}"), 10, 4, r));
    }

    println!("Fig. 6 — inference time/sample (batch {batch}), D={num_vars} Gaussian noise");
    let mut table = Table::new(&[
        "point", "dense t/sample", "sparse t/sample", "speedup",
        "dense mem", "sparse mem",
    ]);
    let mut report_rows: Vec<json::Json> = Vec::new();
    for (label, k, depth, replica) in points {
        let graph =
            einet::structure::random_binary_trees(num_vars, depth, replica, 7);
        let plan = LayeredPlan::compile(graph, k);
        let params = EinetParams::init(&plan, family, 0);
        let mut dense = DenseEngine::new(plan.clone(), family, batch);
        let mut sparse = SparseEngine::new(plan.clone(), family, batch);
        let td = time_forward(&mut dense, &params, &data.data, &mask, batch, repeats);
        let ts = time_forward(&mut sparse, &params, &data.data, &mask, batch, repeats);
        let mem_d = Engine::memory_footprint(&dense, &params).total();
        let mem_s = Engine::memory_footprint(&sparse, &params).total();
        table.row(vec![
            label.clone(),
            fmt_si(td / batch as f64),
            fmt_si(ts / batch as f64),
            format!("{:.1}x", ts / td),
            fmt_bytes(mem_d),
            fmt_bytes(mem_s),
        ]);
        println!(
            "{:<6} dense {}/sample  sparse {}/sample  speedup {:.1}x",
            label,
            fmt_si(td / batch as f64),
            fmt_si(ts / batch as f64),
            ts / td
        );
        report_rows.push(json::obj(vec![
            ("point", json::s(&label)),
            ("dense_sample_s", json::num(td / batch as f64)),
            ("sparse_sample_s", json::num(ts / batch as f64)),
            ("speedup", json::num(ts / td)),
            ("dense_mem_bytes", json::num(mem_d as f64)),
            ("sparse_mem_bytes", json::num(mem_s as f64)),
        ]));
    }
    println!("\n{}", table.render());
    let report = json::obj(vec![
        ("experiment", json::s("fig6_inference")),
        ("quick", json::num(quick as i32 as f64)),
        ("num_vars", json::num(num_vars as f64)),
        ("batch", json::num(batch as f64)),
        ("rows", json::arr(report_rows)),
    ]);
    std::fs::write("BENCH_fig6.json", report.to_string()).expect("write BENCH_fig6.json");
    println!("wrote BENCH_fig6.json");
}
