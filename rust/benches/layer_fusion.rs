//! Layer-fusion speedup: dense (step-at-a-time) vs fused (superblock)
//! execution of the SAME lowered plan, bit-identical by contract — so
//! every ratio here is pure dispatch/sweep amortization, no numerical
//! trade.
//!
//! The sweep covers K ∈ {4, 8, 16, 32} × RAT depth, forward rows/s
//! under both semirings plus a full EM step (forward + backward +
//! M-step), at a small serving batch where per-step kernel dispatch is
//! the bottleneck the fusion removes. Runs in the Fast math tier (the
//! serving configuration; the Exact tier is libm-bound and fusion
//! cannot buy transcendentals back).
//!
//! Results land in BENCH_layers.json (CI artifact) with a `speedup`
//! field per row.
//!
//!     cargo bench --bench layer_fusion            # full size
//!     EINET_BENCH_QUICK=1 cargo bench --bench layer_fusion

use einet::bench::{time_it, Table};
use einet::em::{m_step, EmConfig};
use einet::engine::kernels;
use einet::util::json;
use einet::util::rng::Rng;
use einet::{
    DenseEngine, EinetParams, EmStats, Engine, FusedEngine, LayeredPlan,
    LeafFamily, Semiring,
};

/// Forward-only throughput over the dataset, batch-at-a-time.
#[allow(clippy::too_many_arguments)]
fn forward_rate<E: Engine>(
    e: &mut E,
    params: &EinetParams,
    x: &[f32],
    mask: &[f32],
    n: usize,
    bn: usize,
    row: usize,
    sr: Semiring,
    reps: usize,
) -> f64 {
    let mut logp = vec![0.0f32; bn];
    let mut run = || {
        let mut b0 = 0usize;
        while b0 < n {
            let b = bn.min(n - b0);
            e.forward_semiring(
                params,
                &x[b0 * row..(b0 + b) * row],
                mask,
                &mut logp[..b],
                sr,
            );
            b0 += b;
        }
    };
    run(); // warmup
    let t = time_it(&mut run, 0, reps);
    n as f64 / t.median_s
}

/// One full EM step (forward + E-step over every batch, then the
/// M-step) per timed iteration.
#[allow(clippy::too_many_arguments)]
fn em_rate<E: Engine>(
    e: &mut E,
    params: &EinetParams,
    x: &[f32],
    mask: &[f32],
    n: usize,
    bn: usize,
    row: usize,
    reps: usize,
) -> f64 {
    let em = EmConfig {
        step_size: 0.5,
        ..Default::default()
    };
    let mut logp = vec![0.0f32; bn];
    let mut run = || {
        let mut stats = EmStats::zeros_like(params);
        let mut b0 = 0usize;
        while b0 < n {
            let b = bn.min(n - b0);
            let xb = &x[b0 * row..(b0 + b) * row];
            e.forward(params, xb, mask, &mut logp[..b]);
            e.backward(params, xb, mask, b, &mut stats);
            b0 += b;
        }
        let mut p = params.clone();
        m_step(&mut p, &stats, &em);
    };
    run(); // warmup
    let t = time_it(&mut run, 0, reps);
    n as f64 / t.median_s
}

fn main() {
    let quick = std::env::var("EINET_BENCH_QUICK").is_ok();
    let ks: &[usize] = if quick { &[4, 8] } else { &[4, 8, 16, 32] };
    let depths: &[usize] = if quick { &[3] } else { &[2, 3] };
    let (num_vars, replica) = if quick { (32usize, 4usize) } else { (64, 8) };
    let n = if quick { 192usize } else { 768 };
    // a small serving batch: the dispatch-bound regime layer fusion
    // targets (large batches amortize dispatch on their own)
    let bn = 8usize;
    let reps = if quick { 3 } else { 5 };
    let family = LeafFamily::Bernoulli;

    // the serving tier: vectorized polynomial exp/ln (the Exact tier is
    // transcendental-dominated and blind to call-structure wins)
    kernels::force_fastmath(true);

    let mut rng = Rng::new(11);
    let x: Vec<f32> = (0..n * num_vars)
        .map(|_| if rng.bernoulli(0.5) { 1.0 } else { 0.0 })
        .collect();
    let mask = vec![1.0f32; num_vars];
    let row = num_vars;

    println!(
        "layer fusion — RAT D={num_vars} R={replica}, N={n}, batch={bn}, \
         fast tier, dense vs fused"
    );
    let mut table = Table::new(&[
        "depth", "K", "pass", "dense rows/s", "fused rows/s", "speedup",
    ]);
    let mut rows: Vec<json::Json> = Vec::new();
    let mut emit = |table: &mut Table,
                    rows: &mut Vec<json::Json>,
                    depth: usize,
                    k: usize,
                    pass: &str,
                    rd: f64,
                    rf: f64| {
        let speedup = rf / rd;
        table.row(vec![
            format!("{depth}"),
            format!("{k}"),
            pass.to_string(),
            format!("{rd:.0}"),
            format!("{rf:.0}"),
            format!("{speedup:.2}x"),
        ]);
        println!(
            "depth={depth} K={k} {pass}: dense {rd:.0} rows/s, \
             fused {rf:.0} rows/s ({speedup:.2}x)"
        );
        rows.push(json::obj(vec![
            ("depth", json::num(depth as f64)),
            ("k", json::num(k as f64)),
            ("pass", json::s(pass)),
            ("dense_rows_per_s", json::num(rd)),
            ("fused_rows_per_s", json::num(rf)),
            ("speedup", json::num(speedup)),
        ]));
    };

    for &depth in depths {
        for &k in ks {
            let structure = format!("rat:depth={depth},replica={replica},seed=3");
            let graph = einet::structure::from_spec(num_vars, &structure)
                .expect("structure");
            let plan = LayeredPlan::compile(graph, k);
            let params = EinetParams::init(&plan, family, 5);
            let mut dense = DenseEngine::new(plan.clone(), family, bn);
            let mut fused = FusedEngine::new(plan.clone(), family, bn);
            for (sr, tag) in [
                (Semiring::SumProduct, "forward"),
                (Semiring::MaxProduct, "forward_max"),
            ] {
                let rd =
                    forward_rate(&mut dense, &params, &x, &mask, n, bn, row, sr, reps);
                let rf =
                    forward_rate(&mut fused, &params, &x, &mask, n, bn, row, sr, reps);
                emit(&mut table, &mut rows, depth, k, tag, rd, rf);
            }
            let rd = em_rate(&mut dense, &params, &x, &mask, n, bn, row, reps);
            let rf = em_rate(&mut fused, &params, &x, &mask, n, bn, row, reps);
            emit(&mut table, &mut rows, depth, k, "em_step", rd, rf);
        }
    }
    kernels::force_fastmath(false);

    println!("\n{}", table.render());
    let report = json::obj(vec![
        ("experiment", json::s("layer_fusion")),
        ("quick", json::num(quick as i32 as f64)),
        ("num_vars", json::num(num_vars as f64)),
        ("replica", json::num(replica as f64)),
        ("n", json::num(n as f64)),
        ("batch", json::num(bn as f64)),
        ("math", json::s("fast")),
        ("rows", json::arr(rows)),
    ]);
    std::fs::write("BENCH_layers.json", report.to_string())
        .expect("write BENCH_layers.json");
    println!("wrote BENCH_layers.json");
}
