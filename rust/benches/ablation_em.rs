//! Ablation (Section 3.5): stochastic EM hyper-parameters.
//!
//! The paper replaces full-batch EM with mini-batch EM + gliding averages
//! (Eq. 8/9), introducing a step size λ and a batch size. This bench sweeps
//! both on a DEBD-like dataset and reports the validation LL trajectory:
//! the expected shape is (i) full EM (λ=1, full batch) converges per-epoch
//! but costs a full pass per update; (ii) moderate λ with small batches
//! reaches good likelihood in far fewer passes; (iii) λ too large with
//! small batches oscillates/regresses.
//!
//!     cargo bench --bench ablation_em

use einet::bench::Table;
use einet::coordinator::{evaluate, train_parallel, TrainConfig};
use einet::data::debd;
use einet::em::EmConfig;
use einet::{DenseEngine, EinetParams, LayeredPlan, LeafFamily};

fn main() {
    let ds = debd::load("nltcs").unwrap();
    let family = LeafFamily::Bernoulli;
    let graph = einet::structure::random_binary_trees(ds.num_vars, 3, 6, 0);
    let plan = LayeredPlan::compile(graph, 8);
    let epochs = 4;

    println!(
        "Stochastic-EM ablation on {} (D={}, train={}, {} epochs)",
        ds.name, ds.num_vars, ds.train.n, epochs
    );
    let mut table = Table::new(&["step λ", "batch", "valid LL", "epoch time"]);
    for &(lambda, batch) in &[
        (1.0f32, 8000usize), // full-batch EM (one update per epoch)
        (1.0, 500),
        (0.5, 500),
        (0.5, 100),
        (0.2, 100),
        (0.05, 100),
    ] {
        let mut params = EinetParams::init(&plan, family, 1);
        let cfg = TrainConfig {
            epochs,
            batch_size: batch,
            workers: 4,
            em: EmConfig {
                step_size: lambda,
                ..Default::default()
            },
            log_every: 0,
            ..Default::default()
        };
        let hist = train_parallel::<DenseEngine>(
            &plan, family, &mut params, &ds.train.data, ds.train.n, &cfg,
        );
        let valid =
            evaluate::<DenseEngine>(&plan, family, &params, &ds.valid.data, ds.valid.n, 256);
        let secs: f64 =
            hist.iter().map(|h| h.seconds).sum::<f64>() / hist.len() as f64;
        table.row(vec![
            format!("{lambda}"),
            format!("{batch}"),
            format!("{valid:.4}"),
            format!("{secs:.2}s"),
        ]);
        println!("λ={lambda:<5} batch={batch:<5} valid LL {valid:.4}");
    }
    println!("\n{}", table.render());
}
