#!/usr/bin/env python3
"""Deterministic generator for the committed benchmark fixtures.

Rebuild with `python3 fixtures/gen_fixtures.py` from `rust/`; output is
byte-identical across runs (fixed seeds, no platform-dependent RNG).

Two kinds of fixtures:

* `debd/<name>.{train,valid,test}.data` -- tiny datasets in the exact
  DEBD on-disk format (comma-separated 0/1 rows) with the real variable
  counts of their namesakes, sampled from a first-order Markov chain so
  there is learnable correlation structure. They exist so the
  `dataset_bpd` harness and the EM test suites exercise the *file*
  loaders offline; bits-per-dim numbers on them are comparable across
  commits, not to the paper's table (the real corpora are not
  redistributable).

* `images/digits3.eimg` -- a 3-class labeled binary-image set in the
  `.eimg` container (see `src/data/images.rs`): each class lights a
  distinct 4x4-grid block with a 5% per-pixel flip, so a class-conditional
  EiNet with Bernoulli leaves must reach >= 0.9 classify accuracy.
"""
import os
import random
import struct

HERE = os.path.dirname(os.path.abspath(__file__))

# (name, num_vars, train, valid, test, seed, p0, stay)
DEBD = [
    ("nltcs", 16, 400, 80, 80, 1601, 0.30, 0.82),
    ("msnbc", 17, 400, 80, 80, 1701, 0.25, 0.78),
]


def gen_debd():
    outdir = os.path.join(HERE, "debd")
    os.makedirs(outdir, exist_ok=True)
    for name, nv, ntr, nva, nte, seed, p0, stay in DEBD:
        rng = random.Random(seed)
        # per-variable bias so the chain is not translation-invariant
        bias = [0.15 + 0.7 * rng.random() for _ in range(nv)]

        def row():
            vals = []
            prev = 1 if rng.random() < p0 else 0
            for d in range(nv):
                if d == 0:
                    v = prev
                else:
                    # copy the neighbour with prob `stay`, else redraw
                    # from the per-variable bias
                    v = prev if rng.random() < stay else (
                        1 if rng.random() < bias[d] else 0)
                vals.append(v)
                prev = v
            return ",".join(str(v) for v in vals)

        for split, n in (("train", ntr), ("valid", nva), ("test", nte)):
            path = os.path.join(outdir, f"{name}.{split}.data")
            with open(path, "w") as f:
                for _ in range(n):
                    f.write(row() + "\n")
            print(path)


def gen_images():
    outdir = os.path.join(HERE, "images")
    os.makedirs(outdir, exist_ok=True)
    h = w = 4
    classes = 3
    per_class = 80
    # disjoint lit blocks per class on the 4x4 grid
    blocks = [
        {0, 1, 4, 5, 2},      # class 0: top-left block
        {10, 11, 14, 15, 13}, # class 1: bottom-right block
        {3, 6, 7, 9, 12},     # class 2: anti-diagonal band
    ]
    rng = random.Random(443)
    labels = []
    pixels = []
    for c in range(classes):
        for _ in range(per_class):
            labels.append(c)
            for p in range(h * w):
                lit = p in blocks[c]
                if rng.random() < 0.05:  # 5% flip noise
                    lit = not lit
                pixels.append(255 if lit else 0)
    n = classes * per_class
    path = os.path.join(outdir, "digits3.eimg")
    with open(path, "wb") as f:
        f.write(b"EIMG")
        f.write(struct.pack("<5I", n, h, w, 1, classes))
        f.write(bytes(labels))
        f.write(bytes(pixels))
    print(path)


if __name__ == "__main__":
    gen_debd()
    gen_images()
