//! Cross-module system tests: full pipelines over the pure-rust engines
//! (the AOT/PJRT pipeline is covered in runtime_integration.rs).

use einet::coordinator::server::InferenceServer;
use einet::coordinator::{evaluate, train_parallel, TrainConfig};
use einet::data::{debd, images};
use einet::em::EmConfig;
use einet::infer::inpaint;
use einet::mixture::{EinetMixture, MixtureConfig};
use einet::structure::{poon_domingos, random_binary_trees, PdAxes};
use einet::util::rng::Rng;
use einet::util::stats::welch_t_test;
use einet::{
    DecodeMode, DenseEngine, EinetParams, EmStats, LayeredPlan, LeafFamily,
    SparseEngine,
};

/// Full Table-1-style pipeline on one dataset: synth data -> RAT structure
/// -> parallel stochastic EM -> test LL beats the independence baseline.
#[test]
fn density_estimation_learns_tree_bn() {
    let ds = debd::load("nltcs").unwrap();
    let graph = random_binary_trees(ds.num_vars, 3, 4, 0);
    let plan = LayeredPlan::compile(graph, 6);
    let family = LeafFamily::Bernoulli;
    let mut params = EinetParams::init(&plan, family, 0);
    let cfg = TrainConfig {
        epochs: 4,
        batch_size: 256,
        workers: 4,
        em: EmConfig {
            step_size: 0.5,
            ..Default::default()
        },
        log_every: 0,
        ..Default::default()
    };
    train_parallel::<DenseEngine>(&plan, family, &mut params, &ds.train.data, ds.train.n, &cfg);
    let test_ll = evaluate::<DenseEngine>(&plan, family, &params, &ds.test.data, ds.test.n, 256);
    // independence baseline: product of marginal Bernoullis
    let mut marg = vec![0.0f64; ds.num_vars];
    for i in 0..ds.train.n {
        for d in 0..ds.num_vars {
            marg[d] += ds.train.row(i)[d] as f64;
        }
    }
    let mut indep_ll = 0.0f64;
    for i in 0..ds.test.n {
        for d in 0..ds.num_vars {
            let p = (marg[d] / ds.train.n as f64).clamp(1e-4, 1.0 - 1e-4);
            let x = ds.test.row(i)[d] as f64;
            indep_ll += x * p.ln() + (1.0 - x) * (1.0 - p).ln();
        }
    }
    indep_ll /= ds.test.n as f64;
    assert!(
        test_ll > indep_ll + 0.3,
        "EiNet {test_ll:.3} failed to beat independence {indep_ll:.3}"
    );
}

/// Dense vs sparse engines trained with identical schedules produce
/// statistically indistinguishable test likelihoods (the Table 1 claim).
#[test]
fn engines_reach_parity_on_test_ll() {
    let ds = debd::load("nltcs").unwrap();
    let graph = random_binary_trees(ds.num_vars, 3, 3, 1);
    let plan = LayeredPlan::compile(graph, 4);
    let family = LeafFamily::Bernoulli;
    let em = EmConfig {
        step_size: 0.5,
        ..Default::default()
    };
    let batch = 256;
    let n = 2048.min(ds.train.n);
    let epochs = 3;
    // dense
    let mut p_d = EinetParams::init(&plan, family, 2);
    let cfg = TrainConfig {
        epochs,
        batch_size: batch,
        workers: 2,
        em,
        log_every: 0,
        ..Default::default()
    };
    train_parallel::<DenseEngine>(&plan, family, &mut p_d, ds.train.rows(0, n), n, &cfg);
    // sparse
    let mut p_s = EinetParams::init(&plan, family, 2);
    let mask = vec![1.0f32; ds.num_vars];
    let mut sparse = SparseEngine::new(plan.clone(), family, batch);
    let mut logp = vec![0.0f32; batch];
    for _ in 0..epochs {
        let mut b0 = 0;
        while b0 < n {
            let bn = batch.min(n - b0);
            let xs = ds.train.rows(b0, b0 + bn);
            let mut stats = EmStats::zeros_like(&p_s);
            sparse.forward(&p_s, xs, &mask, &mut logp[..bn]);
            sparse.backward(&p_s, xs, &mask, bn, &mut stats);
            einet::em::m_step(&mut p_s, &stats, &em);
            b0 += bn;
        }
    }
    let per_d = einet::coordinator::per_sample_ll::<DenseEngine>(
        &plan, family, &p_d, &ds.test.data, ds.test.n, 256,
    );
    let per_s = einet::coordinator::per_sample_ll::<DenseEngine>(
        &plan, family, &p_s, &ds.test.data, ds.test.n, 256,
    );
    let t = welch_t_test(&per_d, &per_s);
    assert!(
        t.p_greater > 0.05 && 1.0 - t.p_greater > 0.05,
        "engines diverged: t = {:.3}",
        t.t
    );
}

/// Fig-4-style image pipeline end to end: synthetic digits -> k-means ->
/// per-cluster EiNets on a PD structure -> samples + inpainting.
#[test]
fn image_pipeline_produces_valid_samples_and_inpaintings() {
    let (h, w) = (8usize, 8usize);
    let n = 160;
    let (train, _) = images::svhn_like(n, h, w, 0);
    let graph = poon_domingos(h, w, 2, PdAxes::Vertical);
    let plan = LayeredPlan::compile(graph, 4);
    let cfg = MixtureConfig {
        num_clusters: 3,
        k: 4,
        epochs: 2,
        batch_size: 40,
        em: EmConfig {
            step_size: 0.5,
            var_bounds: (1e-6, 1e-1),
            ..Default::default()
        },
        seed: 0,
    };
    let mut mix = EinetMixture::<DenseEngine>::train(
        plan,
        LeafFamily::Gaussian { channels: 3 },
        &train.data,
        n,
        &cfg,
        |_, _, _| {},
    )
    .unwrap();
    let mut rng = Rng::new(1);
    let samples = mix.sample(4, &mut rng, DecodeMode::Sample);
    assert_eq!(samples.len(), 4 * h * w * 3);
    assert!(samples.iter().all(|v| v.is_finite()));
    // inpaint with left half hidden
    let (test, _) = images::svhn_like(2, h, w, 9);
    let mut emask = vec![1.0f32; h * w];
    for y in 0..h {
        for x in 0..w / 2 {
            emask[y * w + x] = 0.0;
        }
    }
    let out = mix.inpaint(&test.data, &emask, 2, DecodeMode::Argmax, &mut rng);
    // observed pixels unchanged
    for b in 0..2 {
        for d in 0..h * w {
            if emask[d] == 1.0 {
                for c in 0..3 {
                    assert_eq!(
                        out[(b * h * w + d) * 3 + c],
                        test.data[(b * h * w + d) * 3 + c]
                    );
                }
            }
        }
    }
    assert!(out.iter().all(|v| v.is_finite()));
}

/// Gaussian-leaf dense engine + training: continuous data path.
#[test]
fn gaussian_em_improves_on_continuous_data() {
    let nv = 16;
    let n = 256;
    let mut rng = Rng::new(5);
    let mut data = vec![0.0f32; n * nv];
    for b in 0..n {
        let mode = rng.bernoulli(0.5);
        for d in 0..nv {
            let mu = if mode { 0.7 } else { 0.3 };
            data[b * nv + d] = mu + 0.08 * rng.normal() as f32;
        }
    }
    let family = LeafFamily::Gaussian { channels: 1 };
    let graph = random_binary_trees(nv, 2, 2, 3);
    let plan = LayeredPlan::compile(graph, 4);
    let mut params = EinetParams::init(&plan, family, 4);
    let ll0 = evaluate::<DenseEngine>(&plan, family, &params, &data, n, 64);
    let cfg = TrainConfig {
        epochs: 6,
        batch_size: 64,
        workers: 2,
        em: EmConfig {
            step_size: 0.5,
            var_bounds: (1e-5, 0.5),
            ..Default::default()
        },
        log_every: 0,
        ..Default::default()
    };
    train_parallel::<DenseEngine>(&plan, family, &mut params, &data, n, &cfg);
    let ll1 = evaluate::<DenseEngine>(&plan, family, &params, &data, n, 64);
    assert!(ll1 > ll0 + 1.0, "Gaussian EM barely improved: {ll0} -> {ll1}");
}

/// The serving path: concurrent clients against the batched service get
/// exactly the same answers as direct engine calls.
#[test]
fn inference_server_concurrent_consistency() {
    let nv = 12;
    let graph = random_binary_trees(nv, 3, 2, 0);
    let plan = LayeredPlan::compile(graph, 4);
    let params = EinetParams::init(&plan, LeafFamily::Bernoulli, 0);
    let mut direct = DenseEngine::new(plan.clone(), LeafFamily::Bernoulli, 1);
    let mask = vec![1.0f32; nv];
    let server = InferenceServer::start::<DenseEngine>(
        plan,
        LeafFamily::Bernoulli,
        params.clone(),
        32,
        std::time::Duration::from_millis(2),
    );
    let mut handles = Vec::new();
    for t in 0..4 {
        let rxs: Vec<_> = (0..25)
            .map(|i| {
                let x: Vec<f32> = (0..nv)
                    .map(|d| (((t * 25 + i) >> (d % 8)) & 1) as f32)
                    .collect();
                (x.clone(), server.submit(x, mask.clone()))
            })
            .collect();
        handles.push(rxs);
    }
    for rxs in handles {
        for (x, rx) in rxs {
            let got = rx.recv().unwrap();
            let mut want = vec![0.0f32; 1];
            direct.forward(&params, &x, &mask, &mut want);
            assert!((got - want[0]).abs() < 1e-5);
        }
    }
    let stats = server.stop();
    assert_eq!(stats.queries, 100);
}

/// Checkpoint round-trip preserves inference results exactly.
#[test]
fn checkpoint_preserves_model_behaviour() {
    let ds = debd::load("nltcs").unwrap();
    let graph = random_binary_trees(ds.num_vars, 2, 2, 0);
    let plan = LayeredPlan::compile(graph, 4);
    let family = LeafFamily::Bernoulli;
    let mut params = EinetParams::init(&plan, family, 0);
    let cfg = TrainConfig {
        epochs: 1,
        batch_size: 256,
        workers: 2,
        em: EmConfig::default(),
        log_every: 0,
        ..Default::default()
    };
    train_parallel::<DenseEngine>(&plan, family, &mut params, &ds.train.data, ds.train.n, &cfg);
    let path = std::env::temp_dir().join("einet_system_ckpt.bin");
    params.save(&path).unwrap();
    let loaded = EinetParams::load(&path).unwrap();
    assert_eq!(loaded.family(), family);
    let a = evaluate::<DenseEngine>(&plan, family, &params, &ds.test.data, ds.test.n, 128);
    let b = evaluate::<DenseEngine>(&plan, family, &loaded, &ds.test.data, ds.test.n, 128);
    assert_eq!(a, b);
    let _ = std::fs::remove_file(path);
}

/// Inpainting on a trained model beats chance at recovering masked bits.
#[test]
fn trained_inpainting_beats_random_fill() {
    let ds = debd::load("nltcs").unwrap();
    let graph = random_binary_trees(ds.num_vars, 3, 4, 0);
    let plan = LayeredPlan::compile(graph, 6);
    let family = LeafFamily::Bernoulli;
    let mut params = EinetParams::init(&plan, family, 0);
    let cfg = TrainConfig {
        epochs: 3,
        batch_size: 256,
        workers: 4,
        em: EmConfig {
            step_size: 0.5,
            ..Default::default()
        },
        log_every: 0,
        ..Default::default()
    };
    train_parallel::<DenseEngine>(&plan, family, &mut params, &ds.train.data, ds.train.n, &cfg);
    let mut engine = DenseEngine::new(plan, family, 64);
    let nv = ds.num_vars;
    let mut emask = vec![1.0f32; nv];
    for d in nv / 2..nv {
        emask[d] = 0.0;
    }
    let mut rng = Rng::new(2);
    let n_eval = 64;
    let out = inpaint(
        &mut engine,
        &params,
        ds.test.rows(0, n_eval),
        &emask,
        n_eval,
        DecodeMode::Argmax,
        &mut rng,
    );
    let mut correct = 0usize;
    let mut total = 0usize;
    for b in 0..n_eval {
        for d in nv / 2..nv {
            total += 1;
            if (out[b * nv + d] > 0.5) == (ds.test.row(b)[d] > 0.5) {
                correct += 1;
            }
        }
    }
    let acc = correct as f64 / total as f64;
    assert!(acc > 0.6, "inpainting accuracy {acc:.3} no better than chance");
}
