//! Scalar-vs-SIMD kernel bit-identity: the batch-blocked kernels of
//! `engine::kernels` must produce *bit-identical* results on every ISA
//! path, for both semirings, forward and backward — this is the contract
//! that lets the engines adopt them without perturbing the parity /
//! oracle / sharding test wall. Pinned here at two levels:
//!
//! * kernel level — `einsum_block` / `outer_block` and the helper
//!   kernels on randomized operands, scalar vs the best detected ISA,
//!   across every K the RAT/PD structures and the benches use;
//! * engine level — a full forward (both semirings) and backward (EM
//!   statistics) through `DenseEngine` and `SparseEngine` built with
//!   forced-scalar kernels vs detected-SIMD kernels, compared via
//!   `f32::to_bits` across structures, families, and masks.
//!
//! The default math tier rides on the same contract: `MathTier::Exact`
//! `vexp`/`vln` sweeps must replay libm per element (pinned below), so
//! staging arguments into a buffer and sweeping once is bitwise the same
//! as the pre-tier per-element `.exp()`/`.ln()` calls. The fast tier's
//! own (ULP-bounded, not bitwise) contract lives in `fastmath_tier.rs`.

use einet::engine::exec::Semiring;
use einet::engine::kernels::{self, Isa, MathTier};
use einet::structure::{poon_domingos, random_binary_trees, PdAxes};
use einet::util::rng::Rng;
use einet::{
    DenseEngine, EinetParams, EmStats, Engine, LayeredPlan, LeafFamily,
    SparseEngine,
};

// ---------------------------------------------------------------------------
// kernel level
// ---------------------------------------------------------------------------

fn random_operands(
    k: usize,
    ko: usize,
    bb: usize,
    seed: u64,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let k2 = k * k;
    let mut w: Vec<f32> = (0..ko * k2)
        .map(|_| rng.uniform_in(0.005, 1.0) as f32)
        .collect();
    for block in w.chunks_mut(k2) {
        let total: f32 = block.iter().sum();
        for v in block.iter_mut() {
            *v /= total;
        }
    }
    // scaled-exponential children in [0, 1], transposed [k, bb]
    let en_t: Vec<f32> = (0..k * bb).map(|_| rng.uniform() as f32).collect();
    let enp_t: Vec<f32> = (0..k * bb).map(|_| rng.uniform() as f32).collect();
    (w, en_t, enp_t)
}

#[test]
fn einsum_block_scalar_vs_simd_all_k() {
    let isa = Isa::best();
    // every K the RAT/PD suites and the benches use, plus odd sizes for
    // the K² mod 4 tails, and batch blocks exercising the lane tails
    for &k in &[1usize, 2, 3, 4, 5, 8, 10, 16, 32] {
        for &bb in &[1usize, 4, 7, 8, 11, 16] {
            let ko = k;
            let k2 = k * k;
            let (w, en_t, enp_t) = random_operands(k, ko, bb, 31 * k as u64 + bb as u64);
            let mut pt_a = vec![0.0f32; k2 * bb];
            let mut pt_b = vec![0.0f32; k2 * bb];
            kernels::outer_block(Isa::Scalar, &en_t, &enp_t, k, bb, &mut pt_a);
            kernels::outer_block(isa, &en_t, &enp_t, k, bb, &mut pt_b);
            for (i, (a, b)) in pt_a.iter().zip(&pt_b).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "outer_block k={k} bb={bb} [{i}]"
                );
            }
            for sr in [Semiring::SumProduct, Semiring::MaxProduct] {
                let mut acc_a = vec![0.0f32; ko * bb];
                let mut acc_b = vec![0.0f32; ko * bb];
                kernels::einsum_block(Isa::Scalar, sr, &w, &pt_a, k2, ko, bb, &mut acc_a);
                kernels::einsum_block(isa, sr, &w, &pt_a, k2, ko, bb, &mut acc_b);
                for (i, (a, b)) in acc_a.iter().zip(&acc_b).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "einsum_block {sr:?} k={k} bb={bb} [{i}]: {a} vs {b}"
                    );
                }
            }
        }
    }
}

#[test]
fn blocked_kernel_matches_per_row_reduction() {
    // the blocked layout must reproduce the per-row dot4/max4 reduction
    // (the pre-kernel engine path) bit-for-bit: same 4-accumulator order,
    // only the operand addresses differ
    for &k in &[2usize, 3, 4, 8, 10] {
        let (bb, ko, k2) = (11usize, k, k * k);
        let (w, en_t, enp_t) = random_operands(k, k, bb, 77 + k as u64);
        let mut prod_t = vec![0.0f32; k2 * bb];
        kernels::outer_block(Isa::Scalar, &en_t, &enp_t, k, bb, &mut prod_t);
        for sr in [Semiring::SumProduct, Semiring::MaxProduct] {
            let mut acc = vec![0.0f32; ko * bb];
            kernels::einsum_block(Isa::best(), sr, &w, &prod_t, k2, ko, bb, &mut acc);
            for b in 0..bb {
                // row-major product for sample b, as the old path built it
                let mut prow = vec![0.0f32; k2];
                for ii in 0..k {
                    for jj in 0..k {
                        prow[ii * k + jj] = en_t[ii * bb + b] * enp_t[jj * bb + b];
                    }
                }
                for kout in 0..ko {
                    let wrow = &w[kout * k2..(kout + 1) * k2];
                    let want = match sr {
                        Semiring::SumProduct => kernels::dot4(Isa::Scalar, wrow, &prow),
                        Semiring::MaxProduct => kernels::max4(Isa::Scalar, wrow, &prow),
                    };
                    assert_eq!(
                        want.to_bits(),
                        acc[kout * bb + b].to_bits(),
                        "{sr:?} k={k} b={b} kout={kout}"
                    );
                }
            }
        }
    }
}

#[test]
fn helper_kernels_bit_identical_with_edge_values() {
    let isa = Isa::best();
    let mut rng = Rng::new(9);
    for trial in 0..40 {
        let n = 1 + (rng.below(70));
        let mut a: Vec<f32> = (0..n).map(|_| rng.uniform_in(-30.0, 2.0) as f32).collect();
        let b: Vec<f32> = (0..n).map(|_| rng.uniform_in(-30.0, 2.0) as f32).collect();
        // sprinkle the log-domain edge values the engines actually hit
        if n > 2 {
            a[rng.below(n)] = f32::NEG_INFINITY;
            a[rng.below(n)] = 0.0;
        }
        assert_eq!(
            kernels::dot4(Isa::Scalar, &a, &b).to_bits(),
            kernels::dot4(isa, &a, &b).to_bits(),
            "dot4 trial {trial}"
        );
        assert_eq!(
            kernels::max4(Isa::Scalar, &a, &b).to_bits(),
            kernels::max4(isa, &a, &b).to_bits(),
            "max4 trial {trial}"
        );
        assert_eq!(
            kernels::max_add(Isa::Scalar, &a, &b).to_bits(),
            kernels::max_add(isa, &a, &b).to_bits(),
            "max_add trial {trial}"
        );
        let mut d1: Vec<f32> = (0..n).map(|_| rng.uniform() as f32).collect();
        let mut d2 = d1.clone();
        kernels::axpy(Isa::Scalar, &mut d1, &b, 0.713);
        kernels::axpy(isa, &mut d2, &b, 0.713);
        assert_eq!(bits(&d1), bits(&d2), "axpy trial {trial}");
        kernels::add_scalar(Isa::Scalar, &mut d1, &b, -4.25);
        kernels::add_scalar(isa, &mut d2, &b, -4.25);
        assert_eq!(bits(&d1), bits(&d2), "add_scalar trial {trial}");
        let mut m1 = vec![f32::NEG_INFINITY; n];
        let mut m2 = m1.clone();
        kernels::vmax_inplace(Isa::Scalar, &mut m1, &a);
        kernels::vmax_inplace(isa, &mut m2, &a);
        assert_eq!(bits(&m1), bits(&m2), "vmax trial {trial}");
        kernels::vmax_shift_inplace(Isa::Scalar, &mut m1, &b, -0.5);
        kernels::vmax_shift_inplace(isa, &mut m2, &b, -0.5);
        assert_eq!(bits(&m1), bits(&m2), "vmax_shift trial {trial}");
    }
}

/// The exact-tier guard: under [`MathTier::Exact`] the vectorized
/// `vexp`/`vln` sweeps are *libm replayed per element*, on every ISA —
/// the property that makes the staged-sweep rewrite of the engines'
/// transcendental sites a no-op bitwise, and therefore keeps the whole
/// parity / oracle / sharding wall green with the tier layer in place.
#[test]
fn exact_tier_vexp_vln_replay_libm_bitwise() {
    let mut rng = Rng::new(21);
    for &isa in &[Isa::Scalar, Isa::best()] {
        for n in [1usize, 3, 7, 8, 16, 33, 100] {
            let mut xs: Vec<f32> =
                (0..n).map(|_| rng.uniform_in(-40.0, 5.0) as f32).collect();
            if n > 3 {
                // the log-domain edges the engines actually feed in
                xs[0] = f32::NEG_INFINITY;
                xs[1] = 0.0;
                xs[2] = -87.5;
            }
            let want: Vec<u32> = xs.iter().map(|x| x.exp().to_bits()).collect();
            kernels::vexp(isa, MathTier::Exact, &mut xs);
            assert_eq!(bits(&xs), want, "vexp exact isa={} n={n}", isa.name());

            let mut ys: Vec<f32> =
                (0..n).map(|_| rng.uniform_in(0.0, 3.0) as f32).collect();
            if n > 2 {
                ys[0] = 0.0;
                ys[1] = f32::MIN_POSITIVE / 2.0; // subnormal
            }
            let want: Vec<u32> = ys.iter().map(|y| y.ln().to_bits()).collect();
            kernels::vln(isa, MathTier::Exact, &mut ys);
            assert_eq!(bits(&ys), want, "vln exact isa={} n={n}", isa.name());
        }
    }
}

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|v| v.to_bits()).collect()
}

// ---------------------------------------------------------------------------
// engine level
// ---------------------------------------------------------------------------

/// `force_scalar` is process-global; serialize the engine-level tests so
/// a concurrently built engine cannot blur which kernels each side used.
static FORCE_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn random_batch(family: LeafFamily, bn: usize, nv: usize, rng: &mut Rng) -> Vec<f32> {
    let od = family.obs_dim();
    let mut x = vec![0.0f32; bn * nv * od];
    for v in x.chunks_mut(od) {
        match family {
            LeafFamily::Bernoulli => {
                v[0] = if rng.bernoulli(0.5) { 1.0 } else { 0.0 };
            }
            LeafFamily::Gaussian { .. } => {
                for c in v.iter_mut() {
                    *c = 0.5 + 0.2 * rng.normal() as f32;
                }
            }
            LeafFamily::Categorical { cats } => {
                v[0] = rng.below(cats) as f32;
            }
            LeafFamily::Binomial { trials } => {
                v[0] = rng.below(trials as usize + 1) as f32;
            }
        }
    }
    x
}

/// Forward under `sr` (+ backward EM statistics under sum-product),
/// returned as raw bits.
fn run_bits<E: Engine>(
    plan: &LayeredPlan,
    family: LeafFamily,
    params: &EinetParams,
    x: &[f32],
    mask: &[f32],
    bn: usize,
    cap: usize,
    sr: Semiring,
) -> (Vec<u32>, Vec<u32>, Vec<u32>) {
    let mut e = E::build(plan.clone(), family, cap);
    let mut logp = vec![0.0f32; bn];
    e.forward_semiring(params, x, mask, &mut logp, sr);
    let mut stats = EmStats::zeros_like(params);
    if sr == Semiring::SumProduct {
        e.backward(params, x, mask, bn, &mut stats);
    }
    (bits(&logp), bits(&stats.grad), bits(&stats.sum_p))
}

fn engine_case<E: Engine>(plan: &LayeredPlan, family: LeafFamily, seed: u64, label: &str) {
    let nv = plan.graph.num_vars;
    // bn == cap exercises whole blocks + lane tails (13 = 8 + 5); a
    // second batch size crosses multiple 16-row blocks
    for (bn, cap) in [(13usize, 13usize), (37, 37)] {
        let mut rng = Rng::new(seed);
        let params = EinetParams::init(plan, family, seed);
        let x = random_batch(family, bn, nv, &mut rng);
        let full = vec![1.0f32; nv];
        let mut partial = full.clone();
        partial[nv / 2] = 0.0;
        partial[nv - 1] = 0.0;
        for mask in [full, partial] {
            for sr in [Semiring::SumProduct, Semiring::MaxProduct] {
                kernels::force_scalar(true);
                let scalar = run_bits::<E>(plan, family, &params, &x, &mask, bn, cap, sr);
                kernels::force_scalar(false);
                let simd = run_bits::<E>(plan, family, &params, &x, &mask, bn, cap, sr);
                assert_eq!(
                    scalar, simd,
                    "{label} family={family:?} bn={bn} {sr:?}: scalar and SIMD engines diverge"
                );
            }
        }
    }
}

#[test]
fn dense_engine_scalar_vs_simd_bit_identical() {
    let _g = FORCE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    for (i, family) in [
        LeafFamily::Bernoulli,
        LeafFamily::Gaussian { channels: 1 },
        LeafFamily::Categorical { cats: 4 },
    ]
    .into_iter()
    .enumerate()
    {
        let rat = LayeredPlan::compile(random_binary_trees(10, 3, 3, i as u64), 4);
        engine_case::<DenseEngine>(&rat, family, 100 + i as u64, "dense/rat");
        let pd = LayeredPlan::compile(poon_domingos(3, 4, 1, PdAxes::Both), 3);
        engine_case::<DenseEngine>(&pd, family, 200 + i as u64, "dense/pd");
    }
    // the bench-sized K values (8, 10) on smaller circuits
    for k in [8usize, 10] {
        let plan = LayeredPlan::compile(random_binary_trees(8, 2, 2, k as u64), k);
        engine_case::<DenseEngine>(&plan, LeafFamily::Bernoulli, 300 + k as u64, "dense/k");
    }
}

#[test]
fn sparse_engine_scalar_vs_simd_bit_identical() {
    let _g = FORCE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let rat = LayeredPlan::compile(random_binary_trees(10, 3, 3, 0), 4);
    engine_case::<SparseEngine>(&rat, LeafFamily::Bernoulli, 400, "sparse/rat");
    let pd = LayeredPlan::compile(poon_domingos(3, 4, 1, PdAxes::Both), 3);
    engine_case::<SparseEngine>(&pd, LeafFamily::Gaussian { channels: 1 }, 401, "sparse/pd");
}

#[test]
fn dense_decode_after_simd_forward_matches_scalar() {
    // the sampler reads forward activations: a Sample-mode batched decode
    // seeded identically must emit identical rows whichever kernels
    // produced the activations
    let _g = FORCE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let plan = LayeredPlan::compile(random_binary_trees(9, 2, 3, 5), 4);
    let family = LeafFamily::Bernoulli;
    let params = EinetParams::init(&plan, family, 5);
    let bn = 13;
    let mut rng = Rng::new(3);
    let x = random_batch(family, bn, 9, &mut rng);
    let mask = [1.0, 0.0, 1.0, 0.0, 0.0, 1.0, 0.0, 0.0, 1.0f32];
    let mut rows = Vec::new();
    for scalar in [true, false] {
        kernels::force_scalar(scalar);
        let mut e = DenseEngine::new(plan.clone(), family, bn);
        let mut logp = vec![0.0f32; bn];
        e.forward(&params, &x, &mask, &mut logp);
        let mut out = x.clone();
        let mut drng = Rng::new(11);
        e.decode_batch(&params, bn, &mask, einet::DecodeMode::Sample, &mut drng, &mut out);
        rows.push(out);
    }
    kernels::force_scalar(false);
    assert_eq!(rows[0], rows[1], "decode over scalar vs SIMD activations");
}

// ---------------------------------------------------------------------------
// tune_block_rows: autotuner edge cases
// ---------------------------------------------------------------------------

#[test]
fn tune_block_rows_edge_cases() {
    for isa in [Isa::Scalar, Isa::best()] {
        let lane = isa.lanes();
        // K = 1 (the root level): still a positive block size
        for cap in [1usize, 3, 64, 1000] {
            let bb = kernels::tune_block_rows(1, cap, isa);
            assert!(bb >= 1 && bb <= cap, "k=1 cap={cap} {isa:?}: bb={bb}");
        }
        // K not a multiple of the lane width: the chosen block is still
        // a lane multiple unless the batch capacity truncates it
        for k in [3usize, 5, 7, 11, 13] {
            let bb = kernels::tune_block_rows(k, 4096, isa);
            assert!(bb >= 1, "k={k} {isa:?}: empty block");
            assert_eq!(bb % lane, 0, "k={k} {isa:?}: bb={bb} not lane-aligned");
            assert!(bb <= 64, "k={k} {isa:?}: bb={bb} above the clamp");
        }
        // batch capacity smaller than one lane-aligned block: the cap
        // wins (a partial block, never zero, never above the capacity)
        for k in [1usize, 4, 8, 64] {
            for cap in 1..2 * lane {
                let bb = kernels::tune_block_rows(k, cap, isa);
                assert!(
                    bb >= 1 && bb <= cap,
                    "k={k} cap={cap} {isa:?}: bb={bb} outside [1, cap]"
                );
            }
        }
        // huge K: the working set overflows the L1 budget; the tuner
        // falls back to the lane floor instead of zero
        let bb = kernels::tune_block_rows(512, 4096, isa);
        assert!(bb >= 1 && bb % lane == 0, "k=512 {isa:?}: bb={bb}");
        // deterministic in (k, cap, isa): sharded workers must agree
        for k in [1usize, 4, 7, 32] {
            assert_eq!(
                kernels::tune_block_rows(k, 256, isa),
                kernels::tune_block_rows(k, 256, isa)
            );
        }
    }
}
