//! Brute-force enumeration oracle for the unified Query API.
//!
//! On tiny binary circuits (<= 12 variables) every query answer can be
//! computed independently of the engines: an external recursive
//! evaluator walks the region graph (this file — it shares NO code with
//! `engine::exec`) and enumeration closes the marginalization /
//! maximization. Pinned here:
//!
//! * `Marginal` == logsumexp over all completions of the evidence;
//! * `Conditional` == the enumerated joint/evidence ratio;
//! * `Mpe` score == the enumerated `max` over completions of the
//!   max-product circuit value (the exact `max_{z, x_u} p(x_e, x_u, z)`),
//!   and the decoded completion ACHIEVES that max;
//! * on a constructed counterexample the greedy `Argmax` walk provably
//!   returns a worse completion than `Query::Mpe` under the true
//!   density — and `Mpe` matches the enumerated true argmax;
//! * sharded execution (4 segments) answers `Marginal` and `Mpe`
//!   bit-identically to the single engine, across dense/sparse and
//!   RAT/PD structures;
//! * the **Viterbi E-step** (`backward_semiring` under `MaxProduct`)
//!   accumulates exactly the hard-count statistics of the MPE *latent*
//!   assignment, pinned against an independent enumeration of every
//!   induced selection tree of the circuit;
//! * `Classify` / `Posterior` on class-conditional circuits match
//!   per-class exhaustive marginals.

use einet::structure::{poon_domingos, random_binary_trees, PdAxes};
use einet::util::rng::Rng;
use einet::{
    boxed_build, DecodeMode, DenseEngine, EinetParams, EmStats, Engine,
    FusedEngine, LayeredPlan, LeafFamily, ParamLayout, Query, QueryOutput,
    Semiring, SparseEngine,
};

// ---------------------------------------------------------------------------
// independent oracle: recursive region-graph evaluation in f64
// ---------------------------------------------------------------------------

fn logsumexp(terms: &[f64]) -> f64 {
    let m = terms.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    if m == f64::NEG_INFINITY {
        return m;
    }
    m + terms.iter().map(|&t| (t - m).exp()).sum::<f64>().ln()
}

/// (level, slot) of a partition in the layered plan.
fn part_pos(plan: &LayeredPlan, pid: usize) -> (usize, usize) {
    for (i, lv) in plan.levels.iter().enumerate() {
        if let Some(s) = lv.einsum.partition_ids.iter().position(|&p| p == pid) {
            return (i, s);
        }
    }
    unreachable!("partition {pid} not on any level");
}

/// The region's log-value vector for a FULLY observed binary assignment
/// `x` (`[D]`, Bernoulli), under sum-product or max-product semantics.
fn oracle_region(
    plan: &LayeredPlan,
    params: &EinetParams,
    x: &[f32],
    max_product: bool,
    rid: usize,
    memo: &mut Vec<Option<Vec<f64>>>,
) -> Vec<f64> {
    if let Some(v) = &memo[rid] {
        return v.clone();
    }
    let region = &plan.graph.regions[rid];
    let k = plan.k;
    let fam = params.family();
    let s_dim = fam.stat_dim();
    let r_total = plan.num_replica;
    let value = if region.is_leaf() {
        let rep = region.replica.unwrap();
        let mut v = vec![0.0f64; k];
        for d in region.scope.iter() {
            for (kk, acc) in v.iter_mut().enumerate() {
                let c = (d * k + kk) * r_total + rep;
                let th = &params.theta()[c * s_dim..(c + 1) * s_dim];
                *acc += fam.log_prob(th, &x[d..d + 1]) as f64;
            }
        }
        v
    } else {
        // all of a region's partitions live on one level
        let (lvl, _) = part_pos(plan, region.partitions[0]);
        let ko = plan.levels[lvl].einsum.ko;
        let mut per_part: Vec<Vec<f64>> = Vec::new();
        for &pid in &region.partitions {
            let (i, s) = part_pos(plan, pid);
            assert_eq!(i, lvl);
            let p = plan.graph.partitions[pid];
            let lv = oracle_region(plan, params, x, max_product, p.left, memo);
            let rv = oracle_region(plan, params, x, max_product, p.right, memo);
            let w = params.w(i);
            let mut out = vec![0.0f64; ko];
            for (kout, o) in out.iter_mut().enumerate() {
                let mut terms = Vec::with_capacity(k * k);
                for (ii, &l) in lv.iter().enumerate() {
                    for (jj, &r) in rv.iter().enumerate() {
                        let wv = w[(s * ko + kout) * k * k + ii * k + jj] as f64;
                        terms.push(wv.ln() + l + r);
                    }
                }
                *o = if max_product {
                    terms.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
                } else {
                    logsumexp(&terms)
                };
            }
            per_part.push(out);
        }
        if per_part.len() == 1 {
            per_part.pop().unwrap()
        } else {
            let m = plan.levels[lvl].mixing.as_ref().expect("mixing layer");
            let j = m
                .region_ids
                .iter()
                .position(|&r| r == rid)
                .expect("region row");
            let mix = params.mix(lvl).expect("mixing weights");
            let mut out = vec![0.0f64; ko];
            for (kout, o) in out.iter_mut().enumerate() {
                let terms: Vec<f64> = per_part
                    .iter()
                    .enumerate()
                    .map(|(c, pv)| (mix[j * m.cmax + c] as f64).ln() + pv[kout])
                    .collect();
                *o = if max_product {
                    terms.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
                } else {
                    logsumexp(&terms)
                };
            }
            out
        }
    };
    memo[rid] = Some(value.clone());
    value
}

/// Root log-value of a fully observed assignment (f64, independent of
/// the engines).
fn oracle_value(
    plan: &LayeredPlan,
    params: &EinetParams,
    x: &[f32],
    max_product: bool,
) -> f64 {
    let mut memo = vec![None; plan.graph.regions.len()];
    let v = oracle_region(plan, params, x, max_product, plan.graph.root, &mut memo);
    assert_eq!(v.len(), 1, "root must have a scalar value");
    v[0]
}

/// Every completion of `x` over the unobserved (`mask[d] == 0`) dims.
fn completions(x: &[f32], mask: &[f32]) -> Vec<Vec<f32>> {
    let free: Vec<usize> = (0..mask.len()).filter(|&d| mask[d] == 0.0).collect();
    let mut out = Vec::with_capacity(1 << free.len());
    for bits in 0..(1usize << free.len()) {
        let mut c = x.to_vec();
        for (j, &d) in free.iter().enumerate() {
            c[d] = ((bits >> j) & 1) as f32;
        }
        out.push(c);
    }
    out
}

fn oracle_cases() -> Vec<(&'static str, LayeredPlan)> {
    vec![
        (
            "rat",
            LayeredPlan::compile(random_binary_trees(8, 2, 2, 3), 3),
        ),
        (
            "pd",
            LayeredPlan::compile(poon_domingos(2, 4, 1, PdAxes::Both), 2),
        ),
    ]
}

fn random_binary(nv: usize, rng: &mut Rng) -> Vec<f32> {
    (0..nv)
        .map(|_| if rng.bernoulli(0.5) { 1.0 } else { 0.0 })
        .collect()
}

fn half_mask(nv: usize) -> Vec<f32> {
    (0..nv).map(|d| if d < nv / 2 { 1.0 } else { 0.0 }).collect()
}

// ---------------------------------------------------------------------------
// Marginal / Conditional vs enumeration
// ---------------------------------------------------------------------------

fn check_marginal_conditional<E: Engine>(label: &str) {
    for (sname, plan) in oracle_cases() {
        let nv = plan.graph.num_vars;
        let params = EinetParams::init(&plan, LeafFamily::Bernoulli, 11);
        let mut engine = E::build(plan.clone(), LeafFamily::Bernoulli, 4);
        let mut rng = Rng::new(5);
        let x = random_binary(nv, &mut rng);
        let emask = half_mask(nv);
        let ctx = format!("{label}/{sname}");

        // marginal: engine score vs enumerated logsumexp
        let mut out = QueryOutput::default();
        let qp = Query::Marginal {
            mask: emask.clone(),
        }
        .compile(nv)
        .unwrap();
        engine.execute(&params, &qp, &x, 1, &mut rng, &mut out);
        let enum_terms: Vec<f64> = completions(&x, &emask)
            .iter()
            .map(|c| oracle_value(&plan, &params, c, false))
            .collect();
        let want = logsumexp(&enum_terms);
        assert!(
            (out.scores[0] as f64 - want).abs() < 1e-3,
            "{ctx}: marginal {} vs enumerated {want}",
            out.scores[0]
        );

        // conditional: first unobserved variable becomes the query
        let mut qmask = vec![0.0f32; nv];
        qmask[nv / 2] = 1.0;
        let mut joint_mask = emask.clone();
        joint_mask[nv / 2] = 1.0;
        let qp = Query::Conditional {
            query_mask: qmask,
            evidence_mask: emask.clone(),
        }
        .compile(nv)
        .unwrap();
        engine.execute(&params, &qp, &x, 1, &mut rng, &mut out);
        let joint: Vec<f64> = completions(&x, &joint_mask)
            .iter()
            .map(|c| oracle_value(&plan, &params, c, false))
            .collect();
        let want = logsumexp(&joint) - logsumexp(&enum_terms);
        assert!(
            (out.scores[0] as f64 - want).abs() < 1e-3,
            "{ctx}: conditional {} vs enumerated {want}",
            out.scores[0]
        );
    }
}

#[test]
fn marginal_and_conditional_match_enumeration_dense() {
    check_marginal_conditional::<DenseEngine>("dense");
}

#[test]
fn marginal_and_conditional_match_enumeration_sparse() {
    check_marginal_conditional::<SparseEngine>("sparse");
}

// ---------------------------------------------------------------------------
// MPE vs enumeration
// ---------------------------------------------------------------------------

fn check_mpe_exact<E: Engine>(label: &str) {
    for (sname, plan) in oracle_cases() {
        let nv = plan.graph.num_vars;
        let params = EinetParams::init(&plan, LeafFamily::Bernoulli, 23);
        let mut engine = E::build(plan.clone(), LeafFamily::Bernoulli, 4);
        let mut rng = Rng::new(9);
        let ctx = format!("{label}/{sname}");
        for trial in 0..3 {
            let x = random_binary(nv, &mut rng);
            let emask = if trial == 0 {
                vec![0.0f32; nv] // fully unobserved MPE
            } else {
                half_mask(nv)
            };
            let mut out = QueryOutput::default();
            let qp = Query::Mpe { mask: emask.clone() }.compile(nv).unwrap();
            engine.execute(&params, &qp, &x, 1, &mut rng, &mut out);
            // enumerated max over completions of the max-product value
            let mut best = f64::NEG_INFINITY;
            for c in completions(&x, &emask) {
                best = best.max(oracle_value(&plan, &params, &c, true));
            }
            assert!(
                (out.scores[0] as f64 - best).abs() < 1e-3,
                "{ctx} trial {trial}: MPE score {} vs enumerated {best}",
                out.scores[0]
            );
            // the decoded completion achieves the enumerated max
            let decoded = &out.rows[..nv];
            for (d, &m) in emask.iter().enumerate() {
                if m != 0.0 {
                    assert_eq!(decoded[d], x[d], "{ctx}: evidence overwritten");
                } else {
                    assert!(
                        decoded[d] == 0.0 || decoded[d] == 1.0,
                        "{ctx}: non-binary MPE completion"
                    );
                }
            }
            let achieved = oracle_value(&plan, &params, decoded, true);
            assert!(
                (achieved - best).abs() < 1e-3,
                "{ctx} trial {trial}: decoded completion scores {achieved}, \
                 enumerated max is {best}"
            );
        }
    }
}

#[test]
fn mpe_matches_enumerated_max_product_dense() {
    check_mpe_exact::<DenseEngine>("dense");
}

#[test]
fn mpe_matches_enumerated_max_product_sparse() {
    check_mpe_exact::<SparseEngine>("sparse");
}

// ---------------------------------------------------------------------------
// the constructed counterexample: greedy Argmax provably fails
// ---------------------------------------------------------------------------

#[test]
fn mpe_beats_the_greedy_walk_on_the_constructed_counterexample() {
    // Two Bernoulli variables, K = 2, one root partition. Component 0 is
    // sharply concentrated (p = 0.99 on both vars), component 1 is
    // near-uniform (p = 0.45). The root weight matrix puts its largest
    // single weight on the (1, 1) component pair:
    //
    //   W = [[0.35, 0.125], [0.125, 0.40]]
    //
    // Unconditional greedy decode sees identical (log 1 = 0) child
    // activations everywhere, so it follows argmax W = (1, 1) into the
    // near-uniform components and emits their means (0.45 -> 0 after
    // thresholding): completion (0, 0), p ~ 0.12. Max-product weighs the
    // weights BY the best completion density: 0.35 * 0.99^2 = 0.343
    // beats 0.40 * 0.55^2 = 0.121, so Query::Mpe descends into
    // component 0 and emits its modes: completion (1, 1), p ~ 0.54 —
    // which enumeration confirms is the true argmax.
    let plan = LayeredPlan::compile(random_binary_trees(2, 1, 1, 0), 2);
    let nv = 2;
    let family = LeafFamily::Bernoulli;
    let mut params = EinetParams::zeros(ParamLayout::from_plan(&plan, family));
    let logit = |p: f32| (p / (1.0 - p)).ln();
    {
        let theta = params.theta_mut();
        for d in 0..2 {
            theta[d * 2] = logit(0.99); // component 0
            theta[d * 2 + 1] = logit(0.45); // component 1
        }
        let w = params.w_mut(0);
        w[0] = 0.35; // (0, 0)
        w[1] = 0.125; // (0, 1)
        w[2] = 0.125; // (1, 0)
        w[3] = 0.40; // (1, 1)
    }
    params.validate().unwrap();

    for engine_name in ["dense", "sparse"] {
        let mut engine = einet::EngineRegistry::builtin()
            .build(engine_name, plan.clone(), family, 4)
            .unwrap();
        let zeros = vec![0.0f32; nv];
        let no_evidence = vec![0.0f32; nv];

        // exact MPE
        let (mpe_rows, mpe_scores) =
            einet::infer::mpe(engine.as_mut(), &params, &zeros, &no_evidence, 1);
        assert_eq!(
            &mpe_rows[..],
            &[1.0, 1.0],
            "{engine_name}: MPE must pick the concentrated component's modes"
        );

        // greedy walk, thresholded into the Bernoulli domain
        let mut rng = Rng::new(0);
        let mut greedy = einet::infer::inpaint(
            engine.as_mut(),
            &params,
            &zeros,
            &no_evidence,
            1,
            DecodeMode::Argmax,
            &mut rng,
        );
        for v in greedy.iter_mut() {
            *v = if *v > 0.5 { 1.0 } else { 0.0 };
        }
        assert_eq!(
            &greedy[..],
            &[0.0, 0.0],
            "{engine_name}: the counterexample must trap the greedy walk"
        );

        // true densities via full-mask forward: MPE's completion wins,
        // and enumeration confirms it is the global argmax
        let full = vec![1.0f32; nv];
        let mut lp = vec![0.0f32; 1];
        engine.forward(&params, &mpe_rows, &full, &mut lp);
        let p_mpe = lp[0];
        engine.forward(&params, &greedy, &full, &mut lp);
        let p_greedy = lp[0];
        assert!(
            p_mpe > p_greedy + 1.0,
            "{engine_name}: MPE {p_mpe} must clearly beat greedy {p_greedy}"
        );
        let mut best_state = vec![0.0f32; nv];
        let mut best_lp = f32::NEG_INFINITY;
        for s in 0..4usize {
            let c = vec![(s & 1) as f32, ((s >> 1) & 1) as f32];
            engine.forward(&params, &c, &full, &mut lp);
            if lp[0] > best_lp {
                best_lp = lp[0];
                best_state = c;
            }
        }
        assert_eq!(
            best_state, mpe_rows,
            "{engine_name}: MPE must match the enumerated true argmax here"
        );
        // and the reported MPE score equals the max-product oracle
        let want = oracle_value(&plan, &params, &mpe_rows, true);
        assert!(
            (mpe_scores[0] as f64 - want).abs() < 1e-4,
            "{engine_name}: MPE score {} vs oracle {want}",
            mpe_scores[0]
        );
    }
}

// ---------------------------------------------------------------------------
// sharded bit-identity: 1-shard == 4-shard == single engine
// ---------------------------------------------------------------------------

fn check_sharded_mpe<E: Engine + Send + 'static>(label: &str) {
    use einet::coordinator::ShardedPool;
    for (sname, plan) in [
        (
            "rat",
            LayeredPlan::compile(random_binary_trees(12, 3, 3, 2), 3),
        ),
        (
            "pd",
            LayeredPlan::compile(poon_domingos(3, 4, 1, PdAxes::Both), 3),
        ),
    ] {
        let nv = plan.graph.num_vars;
        let family = LeafFamily::Bernoulli;
        let params = EinetParams::init(&plan, family, 31);
        let bn = 5;
        let mut rng = Rng::new(8);
        let x: Vec<f32> = (0..bn * nv)
            .map(|_| if rng.bernoulli(0.5) { 1.0 } else { 0.0 })
            .collect();
        let emask = half_mask(nv);
        let ctx = format!("{label}/{sname}");

        // single-engine reference: max-product forward + Mpe backtrack
        let mut engine = E::build(plan.clone(), family, bn);
        let mut lp_ref = vec![0.0f32; bn];
        engine.forward_semiring(&params, &x, &emask, &mut lp_ref, Semiring::MaxProduct);
        let mut rows_ref = x.clone();
        engine.decode_batch(
            &params,
            bn,
            &emask,
            DecodeMode::Mpe,
            &mut Rng::new(1),
            &mut rows_ref,
        );

        for shards in [1usize, 4] {
            let mut pool =
                ShardedPool::new(boxed_build::<E>, &plan, family, &params, shards, bn);
            let mut lp = vec![0.0f32; bn];
            pool.forward_shared(
                std::sync::Arc::new(x.clone()),
                0,
                std::sync::Arc::new(emask.clone()),
                bn,
                Semiring::MaxProduct,
                &mut lp,
            )
            .unwrap();
            for (b, (a, g)) in lp_ref.iter().zip(&lp).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    g.to_bits(),
                    "{ctx} shards={shards}: max-product forward row {b} diverged"
                );
            }
            let mut rows = x.clone();
            pool.decode(bn, &emask, DecodeMode::Mpe, &mut Rng::new(1), &mut rows)
                .unwrap();
            for i in 0..bn * nv {
                assert_eq!(
                    rows_ref[i].to_bits(),
                    rows[i].to_bits(),
                    "{ctx} shards={shards}: Mpe completion element {i} diverged"
                );
            }
        }
    }
}

#[test]
fn sharded_mpe_is_bit_identical_dense() {
    check_sharded_mpe::<DenseEngine>("dense");
}

#[test]
fn sharded_mpe_is_bit_identical_sparse() {
    check_sharded_mpe::<SparseEngine>("sparse");
}

// ---------------------------------------------------------------------------
// Viterbi E-step vs enumeration of the MPE latent assignment
// ---------------------------------------------------------------------------

/// One complete latent assignment (induced selection tree) of the
/// circuit for a fully observed sample: its joint log-probability
/// `log p(x, z)` and the hard-count statistics its selection implies —
/// additions into the flat `EmStats::grad` buffer (sum/mixing weight
/// counts at their arena offsets, Bernoulli moment sums at the theta
/// offsets) and into `EmStats::sum_p` (one unit of posterior mass per
/// selected leaf component per scope variable).
#[derive(Clone)]
struct Induced {
    logp: f64,
    grad: Vec<(usize, f64)>,
    sump: Vec<usize>,
}

/// Enumerate EVERY induced selection tree below `(rid, kk)`: at a leaf
/// there is exactly one (the component's factorized density over its
/// scope); at a sum the choices multiply — a mixing child per
/// partition, an `(i, j)` component pair per einsum, crossed with the
/// subtree enumerations. Shares no code with `exec::max_backward`.
fn enum_induced(
    plan: &LayeredPlan,
    params: &EinetParams,
    x: &[f32],
    rid: usize,
    kk: usize,
) -> Vec<Induced> {
    let region = &plan.graph.regions[rid];
    let k = plan.k;
    let r_total = plan.num_replica;
    let fam = params.family();
    let s_dim = fam.stat_dim();
    if region.is_leaf() {
        let rep = region.replica.unwrap();
        let mut logp = 0.0f64;
        let mut grad = Vec::new();
        let mut sump = Vec::new();
        for d in region.scope.iter() {
            let base = (d * k + kk) * r_total + rep;
            let th = &params.theta()[base * s_dim..(base + 1) * s_dim];
            logp += fam.log_prob(th, &x[d..d + 1]) as f64;
            sump.push(base);
            // Bernoulli sufficient statistic T(x) = x (the test is
            // Bernoulli-only, s_dim == 1)
            grad.push((base * s_dim, x[d] as f64));
        }
        return vec![Induced { logp, grad, sump }];
    }
    let (lvl, _) = part_pos(plan, region.partitions[0]);
    let ko = plan.levels[lvl].einsum.ko;
    let w_off = params.layout.levels[lvl].w_off;
    let w = params.w(lvl);
    let mut out: Vec<Induced> = Vec::new();
    for (ci, &pid) in region.partitions.iter().enumerate() {
        let (i, s) = part_pos(plan, pid);
        assert_eq!(i, lvl);
        let p = plan.graph.partitions[pid];
        let mut choices: Vec<Induced> = Vec::new();
        for ii in 0..k {
            let lefts = enum_induced(plan, params, x, p.left, ii);
            for jj in 0..k {
                let rights = enum_induced(plan, params, x, p.right, jj);
                let wl = (s * ko + kk) * k * k + ii * k + jj;
                let lw = (w[wl] as f64).ln();
                for l in &lefts {
                    for r in &rights {
                        let mut grad = l.grad.clone();
                        grad.extend(r.grad.iter().cloned());
                        grad.push((w_off + wl, 1.0));
                        let mut sump = l.sump.clone();
                        sump.extend(r.sump.iter().cloned());
                        choices.push(Induced {
                            logp: lw + l.logp + r.logp,
                            grad,
                            sump,
                        });
                    }
                }
            }
        }
        if region.partitions.len() == 1 {
            out = choices;
        } else {
            // mixing: the selection also picks the partition, paying its
            // mixing weight and counting on the mixing statistic
            let m = plan.levels[lvl].mixing.as_ref().expect("mixing layer");
            let j = m
                .region_ids
                .iter()
                .position(|&r| r == rid)
                .expect("region row");
            let mix = params.mix(lvl).expect("mixing weights");
            let lmix = (mix[j * m.cmax + ci] as f64).ln();
            let ml = params.layout.levels[lvl]
                .mix
                .as_ref()
                .expect("mixing layout");
            let midx = ml.off + j * ml.cmax + ci;
            for mut ch in choices {
                ch.logp += lmix;
                ch.grad.push((midx, 1.0));
                out.push(ch);
            }
        }
    }
    out
}

/// Viterbi E-step oracle: on tiny circuits, the max-product forward
/// score equals the best induced tree's `log p(x, z)`, and the
/// `MaxProduct` backward's accumulated statistics equal the best tree's
/// hard counts — for every engine, with and without a mixing layer.
fn check_viterbi_stats<E: Engine>(label: &str) {
    for (sname, plan) in [
        // replicated forest: mixing at the root
        ("rat-mix", LayeredPlan::compile(random_binary_trees(6, 2, 2, 3), 2)),
        // single tree, larger leaf scopes, no mixing
        ("rat-tree", LayeredPlan::compile(random_binary_trees(8, 2, 1, 5), 2)),
    ] {
        let nv = plan.graph.num_vars;
        let family = LeafFamily::Bernoulli;
        let params = EinetParams::init(&plan, family, 17);
        let bn = 4;
        let mut rng = Rng::new(29);
        let mut x = Vec::with_capacity(bn * nv);
        for _ in 0..bn {
            x.extend(random_binary(nv, &mut rng));
        }
        let mask = vec![1.0f32; nv];
        let ctx = format!("{label}/{sname}");

        // enumerate the MPE latent assignment per sample and sum its
        // hard counts into oracle accumulators
        let total = params.layout.total;
        let mut want_grad = vec![0.0f64; total];
        let mut want_sump = vec![0.0f64; params.layout.num_vars * plan.k * plan.num_replica];
        let mut want_ll = 0.0f64;
        let mut want_scores = Vec::with_capacity(bn);
        for b in 0..bn {
            let row = &x[b * nv..(b + 1) * nv];
            let trees = enum_induced(&plan, &params, row, plan.graph.root, 0);
            let best = trees
                .iter()
                .max_by(|a, b| a.logp.partial_cmp(&b.logp).unwrap())
                .unwrap();
            want_scores.push(best.logp);
            want_ll += best.logp;
            for &(i, v) in &best.grad {
                want_grad[i] += v;
            }
            for &c in &best.sump {
                want_sump[c] += 1.0;
            }
        }

        // the engine under max-product: forward scores are the MPE
        // scores, the backward statistics are the hard counts
        let mut engine = E::build(plan.clone(), family, bn);
        let mut logp = vec![0.0f32; bn];
        engine.forward_semiring(&params, &x, &mask, &mut logp, Semiring::MaxProduct);
        for b in 0..bn {
            assert!(
                (logp[b] as f64 - want_scores[b]).abs() < 1e-3,
                "{ctx}: max-product forward row {b}: {} vs enumerated {}",
                logp[b],
                want_scores[b]
            );
        }
        let mut stats = EmStats::zeros_like(&params);
        engine.backward_semiring(&params, &x, &mask, bn, &mut stats, Semiring::MaxProduct);
        assert_eq!(stats.count, bn, "{ctx}: sample count");
        assert!(
            (stats.loglik - want_ll).abs() < 1e-3,
            "{ctx}: Viterbi loglik {} vs enumerated {want_ll}",
            stats.loglik
        );
        for i in 0..total {
            assert!(
                (stats.grad[i] as f64 - want_grad[i]).abs() < 1e-3,
                "{ctx}: Viterbi statistic {i}: {} vs enumerated {}",
                stats.grad[i],
                want_grad[i]
            );
        }
        for (c, (&got, &want)) in stats.sum_p.iter().zip(&want_sump).enumerate() {
            assert!(
                (got as f64 - want).abs() < 1e-3,
                "{ctx}: leaf mass {c}: {got} vs enumerated {want}"
            );
        }
    }
}

#[test]
fn viterbi_stats_match_enumerated_mpe_assignment_dense() {
    check_viterbi_stats::<DenseEngine>("dense");
}

#[test]
fn viterbi_stats_match_enumerated_mpe_assignment_sparse() {
    check_viterbi_stats::<SparseEngine>("sparse");
}

#[test]
fn viterbi_stats_match_enumerated_mpe_assignment_fused() {
    check_viterbi_stats::<FusedEngine>("fused");
}

// ---------------------------------------------------------------------------
// Classify / Posterior vs per-class exhaustive marginals
// ---------------------------------------------------------------------------

/// Per-class evidence scores by enumeration: for each class entry of
/// the widened root, logsumexp the root's class value over every
/// completion of the evidence (the recursive oracle evaluates the
/// widened root vector directly).
fn oracle_class_scores(
    plan: &LayeredPlan,
    params: &EinetParams,
    x: &[f32],
    mask: &[f32],
    classes: usize,
) -> Vec<f64> {
    let mut terms: Vec<Vec<f64>> = vec![Vec::new(); classes];
    for c in completions(x, mask) {
        let mut memo = vec![None; plan.graph.regions.len()];
        let v = oracle_region(plan, params, &c, false, plan.graph.root, &mut memo);
        assert_eq!(v.len(), classes, "widened root must carry one value per class");
        for (ci, &s) in v.iter().enumerate() {
            terms[ci].push(s);
        }
    }
    terms.iter().map(|t| logsumexp(t)).collect()
}

fn check_class_queries<E: Engine>(label: &str) {
    for (sname, classes, plan) in [
        (
            "rat-tree",
            3usize,
            LayeredPlan::compile(random_binary_trees(6, 2, 1, 4), 2),
        ),
        (
            "rat-mix",
            4usize,
            LayeredPlan::compile(random_binary_trees(8, 2, 2, 6), 2),
        ),
    ] {
        let plan = plan.with_classes(classes).expect("widen root");
        let nv = plan.graph.num_vars;
        let params = EinetParams::init(&plan, LeafFamily::Bernoulli, 37);
        let bn = 3;
        let mut engine = E::build(plan.clone(), LeafFamily::Bernoulli, bn);
        let mut rng = Rng::new(41);
        let mut x = Vec::with_capacity(bn * nv);
        for _ in 0..bn {
            x.extend(random_binary(nv, &mut rng));
        }
        for (mname, mask) in [("full", vec![1.0f32; nv]), ("half", half_mask(nv))] {
            let ctx = format!("{label}/{sname}/{mname}");
            let want: Vec<Vec<f64>> = (0..bn)
                .map(|b| {
                    oracle_class_scores(
                        &plan,
                        &params,
                        &x[b * nv..(b + 1) * nv],
                        &mask,
                        classes,
                    )
                })
                .collect();

            // Classify: the argmax class (uniform prior, so the evidence
            // argmax IS the posterior argmax)
            let mut out = QueryOutput::default();
            let qp = Query::Classify { mask: mask.clone() }.compile(nv).unwrap();
            engine.execute(&params, &qp, &x, bn, &mut rng, &mut out);
            assert_eq!(out.scores.len(), bn, "{ctx}: one prediction per row");
            for b in 0..bn {
                let best = want[b]
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                assert_eq!(
                    out.scores[b] as usize, best,
                    "{ctx}: Classify row {b} picked {} but the enumerated \
                     per-class marginals favor {best}",
                    out.scores[b]
                );
            }

            // Posterior: log-softmax of the enumerated per-class scores
            let qp = Query::Posterior { mask: mask.clone() }.compile(nv).unwrap();
            engine.execute(&params, &qp, &x, bn, &mut rng, &mut out);
            assert_eq!(out.scores.len(), bn * classes, "{ctx}: [bn, C] posteriors");
            for b in 0..bn {
                let lse = logsumexp(&want[b]);
                let mut mass = 0.0f64;
                for c in 0..classes {
                    let got = out.scores[b * classes + c] as f64;
                    let expect = want[b][c] - lse;
                    assert!(
                        (got - expect).abs() < 1e-3,
                        "{ctx}: posterior row {b} class {c}: {got} vs \
                         enumerated {expect}"
                    );
                    mass += got.exp();
                }
                assert!(
                    (mass - 1.0).abs() < 1e-4,
                    "{ctx}: posterior row {b} is not normalized: mass {mass}"
                );
            }
        }
    }
}

#[test]
fn class_queries_match_exhaustive_marginals_dense() {
    check_class_queries::<DenseEngine>("dense");
}

#[test]
fn class_queries_match_exhaustive_marginals_sparse() {
    check_class_queries::<SparseEngine>("sparse");
}
