//! Layer-fused engine contract: `fused` is the dense layout executed
//! superblock-at-a-time, and **bit-identity with `dense` is the hard
//! contract** — forward log-likelihoods under both semirings, EM
//! statistics, and decoding must match bit-for-bit across structures
//! (RAT replica forests and Poon–Domingos grids), every leaf family,
//! and shard counts (each sharded worker fuses its own segment).
//!
//! Also pinned here: the structural invariants of the superblock
//! lowering (every step fused exactly once, execution order preserved,
//! runs maximal and kind/level-uniform) and the unknown-engine error
//! surfaces (registry lookups and the shard-worker TCP handshake list
//! the registered engine names).

use einet::coordinator::ShardedPool;
use einet::em::{m_step, EmConfig};
use einet::engine::exec::{ExecPlan, Step};
use einet::structure::{poon_domingos, random_binary_trees, PdAxes};
use einet::util::rng::Rng;
use einet::{
    boxed_build, DecodeMode, DenseEngine, EinetParams, EmStats, Engine,
    EngineRegistry, FusedEngine, LayerPlan, LayeredPlan, LeafFamily, Semiring,
    Superblock,
};

/// Draw a batch of valid observations for the family.
fn random_batch(family: LeafFamily, bn: usize, nv: usize, rng: &mut Rng) -> Vec<f32> {
    let od = family.obs_dim();
    let mut x = vec![0.0f32; bn * nv * od];
    for v in x.chunks_mut(od) {
        match family {
            LeafFamily::Bernoulli => {
                v[0] = if rng.bernoulli(0.5) { 1.0 } else { 0.0 };
            }
            LeafFamily::Gaussian { .. } => {
                for c in v.iter_mut() {
                    *c = 0.5 + 0.2 * rng.normal() as f32;
                }
            }
            LeafFamily::Categorical { cats } => {
                v[0] = rng.below(cats) as f32;
            }
            LeafFamily::Binomial { trials } => {
                v[0] = rng.below(trials as usize + 1) as f32;
            }
        }
    }
    x
}

fn all_families() -> Vec<LeafFamily> {
    vec![
        LeafFamily::Bernoulli,
        LeafFamily::Gaussian { channels: 1 },
        LeafFamily::Gaussian { channels: 3 },
        LeafFamily::Categorical { cats: 4 },
        LeafFamily::Binomial { trials: 6 },
    ]
}

fn test_plans() -> Vec<(LayeredPlan, &'static str)> {
    vec![
        (
            LayeredPlan::compile(random_binary_trees(10, 3, 3, 7), 4),
            "rat",
        ),
        (
            LayeredPlan::compile(poon_domingos(3, 4, 1, PdAxes::Both), 3),
            "pd",
        ),
    ]
}

// ---------------------------------------------------------------------------
// structural invariants of the superblock lowering
// ---------------------------------------------------------------------------

fn step_kind_level(ep: &ExecPlan, si: usize) -> (u8, usize) {
    match ep.steps[si] {
        Step::Leaf { .. } => (0, 0),
        Step::Einsum { level, .. } => (1, level),
        Step::Mix { level, .. } => (2, level),
    }
}

fn assert_valid_fusion(ep: &ExecPlan, lp: &LayerPlan, steps: &[usize], ctx: &str) {
    // every step fused exactly once, in its original execution order
    let flat: Vec<usize> = lp.blocks.iter().flat_map(|b| b.steps()).copied().collect();
    assert_eq!(flat, steps, "{ctx}: flattening must recover the step list");
    assert_eq!(lp.n_steps(), steps.len(), "{ctx}: n_steps");
    // each superblock is kind/level-uniform, its enum variant matches
    // its steps, and adjacent superblocks differ (runs are maximal)
    let mut prev: Option<(u8, usize)> = None;
    for block in &lp.blocks {
        assert!(!block.steps().is_empty(), "{ctx}: empty superblock");
        let kl = step_kind_level(ep, block.steps()[0]);
        for &si in block.steps() {
            assert_eq!(
                step_kind_level(ep, si),
                kl,
                "{ctx}: mixed kind/level inside one superblock"
            );
        }
        match (block, kl.0) {
            (Superblock::Leaf { .. }, 0) => {}
            (Superblock::Einsum { level, .. }, 1) => assert_eq!(*level, kl.1, "{ctx}"),
            (Superblock::Mix { level, .. }, 2) => assert_eq!(*level, kl.1, "{ctx}"),
            _ => panic!("{ctx}: superblock variant does not match its steps"),
        }
        if let Some(p) = prev {
            assert_ne!(p, kl, "{ctx}: adjacent same-kind same-level superblocks");
        }
        prev = Some(kl);
    }
}

#[test]
fn superblocks_cover_every_step_once_in_depth_order() {
    for (plan, label) in test_plans() {
        let ep = ExecPlan::lower(plan, LeafFamily::Bernoulli, 8);
        let all: Vec<usize> = (0..ep.steps.len()).collect();
        let lp = LayerPlan::fuse(&ep);
        assert_valid_fusion(&ep, &lp, &all, label);
        // the lowering order (leaves, then per level einsums before
        // mixes) means levels never decrease across einsum superblocks
        let mut last_level = 0usize;
        for block in &lp.blocks {
            if let Superblock::Einsum { level, .. } = block {
                assert!(
                    *level >= last_level,
                    "{label}: einsum superblock levels must ascend"
                );
                last_level = *level;
            }
        }
    }
}

#[test]
fn segment_fusion_covers_each_workers_steps() {
    use einet::PlanPartition;
    for (plan, label) in test_plans() {
        let ep = ExecPlan::lower(plan, LeafFamily::Bernoulli, 8);
        for shards in [2usize, 4] {
            let part = PlanPartition::cut(&ep, shards);
            let segs = part.shards.iter().chain(std::iter::once(&part.spine));
            for (s, seg) in segs.enumerate() {
                let ctx = format!("{label} shards={shards} seg={s}");
                let lp = LayerPlan::fuse_steps(&ep, &seg.steps);
                assert_valid_fusion(&ep, &lp, &seg.steps, &ctx);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// bitwise identity with the dense engine
// ---------------------------------------------------------------------------

#[test]
fn fused_forward_and_backward_match_dense_bitwise() {
    for (plan, label) in test_plans() {
        let nv = plan.graph.num_vars;
        for (i, family) in all_families().into_iter().enumerate() {
            let seed = 40 + i as u64;
            let mut rng = Rng::new(seed);
            let bn = 6;
            let params = EinetParams::init(&plan, family, seed);
            let x = random_batch(family, bn, nv, &mut rng);
            let mut mask = vec![1.0f32; nv];
            mask[nv / 2] = 0.0; // one marginalized variable
            let mut dense = DenseEngine::new(plan.clone(), family, bn);
            let mut fused = FusedEngine::new(plan.clone(), family, bn);
            for sr in [Semiring::SumProduct, Semiring::MaxProduct] {
                let ctx = format!("{label} family={family:?} {sr:?}");
                let mut lp_d = vec![0.0f32; bn];
                let mut lp_f = vec![0.0f32; bn];
                dense.forward_semiring(&params, &x, &mask, &mut lp_d, sr);
                fused.forward_semiring(&params, &x, &mask, &mut lp_f, sr);
                for (b, (d, f)) in lp_d.iter().zip(&lp_f).enumerate() {
                    assert!(d.is_finite(), "{ctx}: dense logp[{b}] not finite");
                    assert_eq!(
                        d.to_bits(),
                        f.to_bits(),
                        "{ctx}: logp[{b}] dense {d} vs fused {f}"
                    );
                }
            }
            // EM statistics from the (sum-product) activations
            let ctx = format!("{label} family={family:?}");
            let mut lp = vec![0.0f32; bn];
            dense.forward(&params, &x, &mask, &mut lp);
            fused.forward(&params, &x, &mask, &mut lp);
            let mut st_d = EmStats::zeros_like(&params);
            let mut st_f = EmStats::zeros_like(&params);
            dense.backward(&params, &x, &mask, bn, &mut st_d);
            fused.backward(&params, &x, &mask, bn, &mut st_f);
            assert_eq!(st_d.count, st_f.count, "{ctx}: count");
            assert_eq!(st_d.loglik, st_f.loglik, "{ctx}: loglik");
            for (i, (d, f)) in st_d.grad.iter().zip(&st_f.grad).enumerate() {
                assert_eq!(d.to_bits(), f.to_bits(), "{ctx}: grad[{i}]");
            }
            for (i, (d, f)) in st_d.sum_p.iter().zip(&st_f.sum_p).enumerate() {
                assert_eq!(d.to_bits(), f.to_bits(), "{ctx}: sum_p[{i}]");
            }
        }
    }
}

#[test]
fn fused_decode_and_sampling_match_dense() {
    for (plan, label) in test_plans() {
        let nv = plan.graph.num_vars;
        let family = LeafFamily::Bernoulli;
        let seed = 91;
        let mut rng = Rng::new(seed);
        let bn = 5;
        let params = EinetParams::init(&plan, family, seed);
        let x = random_batch(family, bn, nv, &mut rng);
        let mut mask = vec![1.0f32; nv];
        for d in nv / 2..nv {
            mask[d] = 0.0;
        }
        let mut dense = DenseEngine::new(plan.clone(), family, bn);
        let mut fused = FusedEngine::new(plan.clone(), family, bn);
        let mut lp = vec![0.0f32; bn];
        dense.forward(&params, &x, &mask, &mut lp);
        fused.forward(&params, &x, &mask, &mut lp);
        for mode in [DecodeMode::Argmax, DecodeMode::Sample] {
            let ctx = format!("{label} {mode:?}");
            let mut out_d = x.clone();
            let mut out_f = x.clone();
            dense.decode_batch(&params, bn, &mask, mode, &mut Rng::new(7), &mut out_d);
            fused.decode_batch(&params, bn, &mask, mode, &mut Rng::new(7), &mut out_f);
            assert_eq!(out_d, out_f, "{ctx}: decode diverged");
        }
        // unconditional sampling rides the same shared-rows fast path
        let s_d = dense.sample_batch(&params, 16, &mut Rng::new(23), DecodeMode::Sample);
        let s_f = fused.sample_batch(&params, 16, &mut Rng::new(23), DecodeMode::Sample);
        assert_eq!(s_d, s_f, "{label}: sample_batch diverged");
    }
}

#[test]
fn fused_sharding_matches_single_dense_reference() {
    for (plan, label) in test_plans() {
        let nv = plan.graph.num_vars;
        for family in [LeafFamily::Bernoulli, LeafFamily::Gaussian { channels: 1 }] {
            let seed = 55;
            let mut rng = Rng::new(seed);
            let bn = 6;
            let params = EinetParams::init(&plan, family, seed);
            let x = random_batch(family, bn, nv, &mut rng);
            let mut mask = vec![1.0f32; nv];
            mask[0] = 0.0;
            let em = EmConfig {
                step_size: 0.5,
                var_bounds: (1e-3, 10.0),
                ..Default::default()
            };
            // single-engine dense reference
            let mut dense = DenseEngine::new(plan.clone(), family, bn);
            let mut lp_ref = vec![0.0f32; bn];
            dense.forward(&params, &x, &mask, &mut lp_ref);
            let mut st_ref = EmStats::zeros_like(&params);
            dense.backward(&params, &x, &mask, bn, &mut st_ref);
            let mut p_ref = params.clone();
            m_step(&mut p_ref, &st_ref, &em);
            let mut dec_ref = x.clone();
            dense.decode_batch(
                &params,
                bn,
                &mask,
                DecodeMode::Argmax,
                &mut Rng::new(seed + 9),
                &mut dec_ref,
            );
            // fused pools: every worker fuses its own segment
            for shards in [1usize, 4] {
                let ctx = format!("{label} family={family:?} shards={shards}");
                let mut pool = ShardedPool::new(
                    boxed_build::<FusedEngine>,
                    &plan,
                    family,
                    &params,
                    shards,
                    bn,
                );
                let mut lp = vec![0.0f32; bn];
                pool.forward(&x, &mask, bn, &mut lp).unwrap();
                for (b, (r, g)) in lp_ref.iter().zip(&lp).enumerate() {
                    assert_eq!(
                        r.to_bits(),
                        g.to_bits(),
                        "{ctx}: forward row {b}: {r} vs {g}"
                    );
                }
                let mut stats = EmStats::zeros_like(&params);
                pool.backward(&mut stats).unwrap();
                assert_eq!(stats.loglik, st_ref.loglik, "{ctx}: loglik");
                let mut p = params.clone();
                m_step(&mut p, &stats, &em);
                assert_eq!(p.data, p_ref.data, "{ctx}: EM-stepped parameters");
                let mut dec = x.clone();
                pool.decode(
                    bn,
                    &mask,
                    DecodeMode::Argmax,
                    &mut Rng::new(seed + 9),
                    &mut dec,
                )
                .unwrap();
                assert_eq!(dec_ref, dec, "{ctx}: Argmax decode");
                pool.stop();
            }
        }
    }
}

// ---------------------------------------------------------------------------
// unknown-engine errors list the registered names
// ---------------------------------------------------------------------------

#[test]
fn unknown_engine_errors_list_registered_names() {
    let err = EngineRegistry::builtin()
        .factory("no-such-engine")
        .expect_err("unknown engine must fail")
        .to_string();
    for name in ["dense", "sparse", "fused"] {
        assert!(
            err.contains(name),
            "registry error must list '{name}': {err}"
        );
    }
}

#[test]
fn shard_worker_handshake_refusal_lists_registered_names() {
    use einet::coordinator::transport::{spawn_loopback_workers, TcpTransport};
    use einet::WorkerConfig;

    let (addrs, handles) = spawn_loopback_workers(1).unwrap();
    let cfg = WorkerConfig {
        structure: "rat:depth=2,replica=2,seed=1".to_string(),
        weights: "dense".to_string(),
        num_vars: 8,
        k: 3,
        family: LeafFamily::Bernoulli,
        engine: "no-such-engine".to_string(),
        n_shards: 1,
        shard_id: 0,
        batch_cap: 2,
        fastmath: false,
        classes: 1,
    };
    let err = TcpTransport::connect(&addrs[0], &cfg, 8)
        .expect_err("unknown engine must be refused")
        .to_string();
    for h in handles {
        h.join().unwrap();
    }
    for name in ["dense", "sparse", "fused"] {
        assert!(
            err.contains(name),
            "handshake refusal must list '{name}': {err}"
        );
    }
}
