//! Integration tests over the full AOT path: python-lowered HLO artifacts
//! executed through the rust PJRT runtime.
//!
//! These tests need the `pjrt` cargo feature (the vendored xla closure)
//! AND `make artifacts` to have produced `artifacts/` — the Makefile test
//! target guarantees that ordering. Default builds compile the PJRT
//! runtime as a stub, so the whole file is feature-gated.
#![cfg(feature = "pjrt")]

use einet::coordinator::AotTrainer;
use einet::em::EmConfig;
use einet::leaves::LeafFamily;
use einet::runtime::{AotParams, Runtime};
use einet::util::rng::Rng;

fn artifact_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn runtime() -> Runtime {
    Runtime::new(artifact_dir()).expect("artifacts/ present — run `make artifacts`")
}

#[test]
fn manifest_lists_configs() {
    let rt = runtime();
    let names = rt.list().unwrap();
    assert!(names.contains(&"quick_d4".to_string()));
    assert!(!rt.platform().is_empty());
}

#[test]
fn fwd_executes_and_normalizes() {
    // sum of P(x) over all 2^4 binary states must be 1 — through the whole
    // python->HLO->PJRT->rust chain.
    let rt = runtime();
    let meta = rt.meta("quick_d4").unwrap();
    assert_eq!(meta.num_vars, 4);
    assert_eq!(meta.batch, 8);
    let exe = rt.compile(&meta, "fwd").unwrap();
    let params = AotParams::init(&meta, LeafFamily::Bernoulli, 0).unwrap();
    let mask = vec![1.0f32; 4];
    let mut total = 0.0f64;
    // 16 states in two batches of 8
    for half in 0..2 {
        let mut x = vec![0.0f32; 8 * 4];
        for i in 0..8 {
            let state = half * 8 + i;
            for d in 0..4 {
                x[i * 4 + d] = ((state >> d) & 1) as f32;
            }
        }
        let mut inputs = params.input_slices();
        inputs.push(&x);
        inputs.push(&mask);
        let out = exe.run(&inputs).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].len(), 8);
        total += out[0].iter().map(|&l| (l as f64).exp()).sum::<f64>();
    }
    assert!((total - 1.0).abs() < 1e-4, "total {total}");
}

#[test]
fn fwd_marginalization_gives_zero() {
    let rt = runtime();
    let meta = rt.meta("quick_d4").unwrap();
    let exe = rt.compile(&meta, "fwd").unwrap();
    let params = AotParams::init(&meta, LeafFamily::Bernoulli, 1).unwrap();
    let mask = vec![0.0f32; 4];
    let x = vec![0.0f32; 8 * 4];
    let mut inputs = params.input_slices();
    inputs.push(&x);
    inputs.push(&mask);
    let out = exe.run(&inputs).unwrap();
    for &l in &out[0] {
        assert!(l.abs() < 1e-4, "marginalized logp {l}");
    }
}

#[test]
fn train_outputs_match_contract_and_grads_are_sane() {
    let rt = runtime();
    let meta = rt.meta("quick_d4").unwrap();
    let exe = rt.compile(&meta, "train").unwrap();
    let params = AotParams::init(&meta, LeafFamily::Bernoulli, 2).unwrap();
    let mask = vec![1.0f32; 4];
    let mut rng = Rng::new(0);
    let mut x = vec![0.0f32; 8 * 4];
    for v in x.iter_mut() {
        *v = if rng.bernoulli(0.5) { 1.0 } else { 0.0 };
    }
    let mut inputs = params.input_slices();
    inputs.push(&x);
    inputs.push(&mask);
    let out = exe.run(&inputs).unwrap();
    assert_eq!(out.len(), 1 + meta.params.len());
    // shift gradient: per variable, total posterior mass == batch size
    let shift_idx = 1 + meta
        .params
        .iter()
        .position(|p| p.kind == "shift")
        .unwrap();
    let g = &out[shift_idx];
    let kr = meta.k * meta.replica;
    for d in 0..meta.num_vars {
        let mass: f32 = g[d * kr..(d + 1) * kr].iter().sum();
        assert!(
            (mass - meta.batch as f32).abs() < 1e-2,
            "var {d}: posterior mass {mass}"
        );
    }
    // w gradients must be non-negative (they are expected counts / w)
    for (pi, desc) in meta.params.iter().enumerate() {
        if desc.kind == "w" {
            assert!(
                out[1 + pi].iter().all(|&v| v >= -1e-5),
                "negative n-statistic in {}",
                desc.name
            );
        }
    }
}

#[test]
fn aot_trainer_improves_likelihood() {
    // the full L1+L2+L3 training loop: PJRT E-step + rust M-step
    let rt = runtime();
    let em = EmConfig {
        step_size: 0.5,
        ..Default::default()
    };
    let mut trainer = AotTrainer::new(&rt, "quick_d4", 0, em).unwrap();
    let b = trainer.meta.batch;
    let d = trainer.meta.num_vars;
    let mask = vec![1.0f32; d];
    let mut rng = Rng::new(3);
    // a correlated data stream (all-equal bits with noise)
    let gen = |rng: &mut Rng| -> Vec<f32> {
        let mut x = vec![0.0f32; b * d];
        for i in 0..b {
            let z = rng.bernoulli(0.5);
            for j in 0..d {
                let p = if z { 0.9 } else { 0.1 };
                x[i * d + j] = if rng.bernoulli(p) { 1.0 } else { 0.0 };
            }
        }
        x
    };
    let eval = gen(&mut rng);
    let ll0 = trainer.eval_batch(&eval, &mask).unwrap();
    for _ in 0..30 {
        let x = gen(&mut rng);
        trainer.em_step(&x, &mask).unwrap();
    }
    let ll1 = trainer.eval_batch(&eval, &mask).unwrap();
    assert!(
        ll1 > ll0 + 0.1,
        "AOT EM failed to improve: {ll0:.4} -> {ll1:.4}"
    );
}

#[test]
fn aot_agrees_with_rust_dense_engine_on_leaf_math() {
    // Cross-implementation check: a Bernoulli leaf evaluated by the HLO
    // path must match the rust leaf math. We compare full-graph outputs
    // for a 1-variable-marginalized mask where only variable 0 is active
    // in a K=R=structure shared between both sides is impractical (the
    // structures differ), so instead we check the *family* math: the HLO
    // model with all-but-one variable marginalized defines a mixture of
    // Bernoullis over var 0; its total over {0,1} must be 1.
    let rt = runtime();
    let meta = rt.meta("quick_d4").unwrap();
    let exe = rt.compile(&meta, "fwd").unwrap();
    let params = AotParams::init(&meta, LeafFamily::Bernoulli, 5).unwrap();
    let mut mask = vec![0.0f32; 4];
    mask[0] = 1.0;
    let mut x = vec![0.0f32; 8 * 4];
    x[0] = 0.0; // sample 0: var0 = 0
    x[4] = 1.0; // sample 1: var0 = 1
    let mut inputs = params.input_slices();
    inputs.push(&x);
    inputs.push(&mask);
    let out = exe.run(&inputs).unwrap();
    let p0 = (out[0][0] as f64).exp();
    let p1 = (out[0][1] as f64).exp();
    assert!((p0 + p1 - 1.0).abs() < 1e-5, "p0+p1 = {}", p0 + p1);
}
