//! 1-shard vs N-shard parity for the scope-partitioned execution path.
//!
//! Contract (the sharding analogue of `tests/sampling_parity.rs`): for
//! the same seed, a [`ShardedPool`] must reproduce single-engine
//! execution exactly — forward log-likelihoods and `Argmax` decoding
//! bit-for-bit, EM-stepped parameters value-for-value, and `Sample`-mode
//! decoding draw-for-draw (the counter-based per-(sample, region) RNG
//! streams share one salt across all segments, so even the sampled
//! values coincide) — across engines (dense/sparse), structures
//! (RAT replica forests and Poon–Domingos grids, i.e. clean cuts and
//! heavily shared spines), and leaf families.

use einet::coordinator::ShardedPool;
use einet::em::{m_step, EmConfig};
use einet::structure::{poon_domingos, random_binary_trees, PdAxes};
use einet::util::rng::Rng;
use einet::{
    boxed_build, DecodeMode, DenseEngine, EinetParams, EmStats, Engine,
    LayeredPlan, LeafFamily, SparseEngine,
};

/// Draw a batch of valid observations for the family.
fn random_batch(family: LeafFamily, bn: usize, nv: usize, rng: &mut Rng) -> Vec<f32> {
    let od = family.obs_dim();
    let mut x = vec![0.0f32; bn * nv * od];
    for v in x.chunks_mut(od) {
        match family {
            LeafFamily::Bernoulli => {
                v[0] = if rng.bernoulli(0.5) { 1.0 } else { 0.0 };
            }
            LeafFamily::Gaussian { .. } => {
                for c in v.iter_mut() {
                    *c = 0.5 + 0.2 * rng.normal() as f32;
                }
            }
            LeafFamily::Categorical { cats } => {
                v[0] = rng.below(cats) as f32;
            }
            LeafFamily::Binomial { trials } => {
                v[0] = rng.below(trials as usize + 1) as f32;
            }
        }
    }
    x
}

fn parity_case<E: Engine + Send + 'static>(
    plan: &LayeredPlan,
    family: LeafFamily,
    seed: u64,
    label: &str,
) {
    let nv = plan.graph.num_vars;
    let od = family.obs_dim();
    let row = nv * od;
    let bn = 6;
    let mut rng = Rng::new(seed);
    let params = EinetParams::init(plan, family, seed);
    let x = random_batch(family, bn, nv, &mut rng);
    let mut mask = vec![1.0f32; nv];
    for d in nv / 2..nv {
        mask[d] = 0.0;
    }
    let em = EmConfig {
        step_size: 0.5,
        var_bounds: (1e-3, 10.0),
        ..Default::default()
    };

    // single-engine reference: forward, E-step, Argmax + Sample decode
    let mut engine = E::build(plan.clone(), family, bn);
    let mut lp_ref = vec![0.0f32; bn];
    engine.forward(&params, &x, &mask, &mut lp_ref);
    let mut stats_ref = EmStats::zeros_like(&params);
    engine.backward(&params, &x, &mask, bn, &mut stats_ref);
    let mut p_ref = params.clone();
    m_step(&mut p_ref, &stats_ref, &em);
    let mut argmax_ref = x.clone();
    engine.decode_batch(
        &params,
        bn,
        &mask,
        DecodeMode::Argmax,
        &mut Rng::new(seed + 9),
        &mut argmax_ref,
    );
    let mut sample_ref = x.clone();
    engine.decode_batch(
        &params,
        bn,
        &mask,
        DecodeMode::Sample,
        &mut Rng::new(seed + 77),
        &mut sample_ref,
    );

    for shards in [1usize, 4] {
        let ctx = format!("{label} family={family:?} shards={shards}");
        let mut pool =
            ShardedPool::new(boxed_build::<E>, plan, family, &params, shards, bn);
        // forward log-likelihood: bit-identical
        let mut lp = vec![0.0f32; bn];
        pool.forward(&x, &mask, bn, &mut lp).unwrap();
        for (b, (a, g)) in lp_ref.iter().zip(&lp).enumerate() {
            assert!(
                a.to_bits() == g.to_bits(),
                "{ctx}: forward row {b} diverged: {a} vs {g}"
            );
        }
        // EM step: same parameters from the reduced statistics
        let mut stats = EmStats::zeros_like(&params);
        pool.backward(&mut stats).unwrap();
        assert_eq!(stats.count, stats_ref.count, "{ctx}: count");
        assert_eq!(stats.loglik, stats_ref.loglik, "{ctx}: loglik");
        let mut p = params.clone();
        m_step(&mut p, &stats, &em);
        assert_eq!(p.data, p_ref.data, "{ctx}: EM-stepped parameters diverged");
        // Argmax decode: bit-identical
        let mut argmax_out = x.clone();
        pool.decode(
            bn,
            &mask,
            DecodeMode::Argmax,
            &mut Rng::new(seed + 9),
            &mut argmax_out,
        )
        .unwrap();
        for i in 0..bn * row {
            assert!(
                argmax_ref[i].to_bits() == argmax_out[i].to_bits(),
                "{ctx}: Argmax element {i} diverged"
            );
        }
        // Sample decode: the shared salt + per-(sample, region) streams
        // make even the draws identical
        let mut sample_out = x.clone();
        pool.decode(
            bn,
            &mask,
            DecodeMode::Sample,
            &mut Rng::new(seed + 77),
            &mut sample_out,
        )
        .unwrap();
        assert_eq!(sample_ref, sample_out, "{ctx}: Sample decode diverged");
    }
}

fn all_families() -> Vec<LeafFamily> {
    vec![
        LeafFamily::Bernoulli,
        LeafFamily::Gaussian { channels: 1 },
        LeafFamily::Categorical { cats: 4 },
        LeafFamily::Binomial { trials: 6 },
    ]
}

#[test]
fn sharding_parity_rat_dense() {
    for (i, family) in all_families().into_iter().enumerate() {
        let plan = LayeredPlan::compile(random_binary_trees(12, 3, 3, i as u64), 3);
        parity_case::<DenseEngine>(&plan, family, 60 + i as u64, "dense/rat");
    }
}

#[test]
fn sharding_parity_rat_sparse() {
    for (i, family) in all_families().into_iter().enumerate() {
        let plan = LayeredPlan::compile(random_binary_trees(12, 3, 3, i as u64), 3);
        parity_case::<SparseEngine>(&plan, family, 60 + i as u64, "sparse/rat");
    }
}

#[test]
fn sharding_parity_pd_dense() {
    // Poon–Domingos grids share sub-circuits heavily: clusters collapse
    // toward the spine, which must stay correct (if not accelerated)
    for (i, family) in [LeafFamily::Bernoulli, LeafFamily::Gaussian { channels: 1 }]
        .into_iter()
        .enumerate()
    {
        let plan = LayeredPlan::compile(poon_domingos(3, 4, 1, PdAxes::Both), 3);
        parity_case::<DenseEngine>(&plan, family, 80 + i as u64, "dense/pd");
    }
}

#[test]
fn sharding_parity_pd_sparse() {
    for (i, family) in [LeafFamily::Bernoulli, LeafFamily::Gaussian { channels: 1 }]
        .into_iter()
        .enumerate()
    {
        let plan = LayeredPlan::compile(poon_domingos(3, 4, 1, PdAxes::Both), 3);
        parity_case::<SparseEngine>(&plan, family, 80 + i as u64, "sparse/pd");
    }
}

#[test]
fn sharded_training_trajectories_match_across_shard_counts() {
    // several EM steps end-to-end: 1-shard and 3-shard pools walk the
    // exact same parameter trajectory
    use einet::coordinator::{train_sharded, ShardConfig};
    let nv = 14;
    let plan = LayeredPlan::compile(random_binary_trees(nv, 2, 3, 5), 3);
    let family = LeafFamily::Bernoulli;
    let mut rng = Rng::new(31);
    let n = 96;
    let data = random_batch(family, n, nv, &mut rng);
    let mut results: Vec<EinetParams> = Vec::new();
    for shards in [1usize, 3] {
        let mut p = EinetParams::init(&plan, family, 17);
        let cfg = ShardConfig {
            n_shards: shards,
            epochs: 3,
            batch_size: 32,
            em: EmConfig {
                step_size: 0.5,
                ..Default::default()
            },
            log_every: 0,
            ..Default::default()
        };
        let hist = train_sharded(
            boxed_build::<DenseEngine>,
            &plan,
            family,
            &mut p,
            &data,
            n,
            &cfg,
        )
        .unwrap();
        assert_eq!(hist.len(), 3);
        results.push(p);
    }
    assert_eq!(
        results[0].data, results[1].data,
        "shard count changed the training trajectory"
    );
}
