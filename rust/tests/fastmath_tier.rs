//! The fast-math tier's accuracy contract, pinned.
//!
//! `MathTier::Fast` trades bit-exactness for vectorized polynomial
//! `exp`/`ln`. This suite is the contract that trade is held to:
//!
//! * `vexp` within 512 ULP of libm over the engines' full argument range
//!   [-87, 88]; `vln` within 512 ULP or 1e-6 absolute (the absolute
//!   fallback covers results near ln(1) = 0, where ULPs shrink to
//!   nothing);
//! * IEEE edge semantics — exp: -inf→0, flush below -87, finite
//!   saturation above +88, NaN→NaN, exp(0)=1 exactly; ln: ±0→-inf,
//!   negative/NaN→NaN, +inf→finite, ln(1)=0 exactly;
//! * every ISA path of the Fast tier is bit-identical to its scalar
//!   lane, and the one-off `exp1`/`ln1` calls are bit-identical to the
//!   batched sweeps (so engines may mix them freely);
//! * end-to-end: a Fast-tier engine's log-likelihoods drift from the
//!   Exact tier by well under the parity tolerance, EM statistics stay
//!   finite, and dense/sparse still agree with each other under Fast.
//!
//! The Exact tier's own guard (bitwise libm replay) is in
//! `kernel_identity.rs`. Tier forcing is process-global, so every test
//! that flips it holds `TIER_LOCK` and restores the default before
//! releasing.

use einet::engine::kernels::{self, Isa, MathTier};
use einet::structure::{poon_domingos, random_binary_trees, PdAxes};
use einet::util::rng::Rng;
use einet::{
    DenseEngine, EinetParams, EmStats, Engine, LayeredPlan, LeafFamily,
    SparseEngine,
};

/// `force_fastmath` is process-global; serialize the tests that flip it.
static TIER_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

/// Monotone integer key: consecutive floats (of either sign) map to
/// consecutive integers, so |key(a) - key(b)| counts the ULP steps
/// between them.
fn ulp_key(x: f32) -> i64 {
    let i = x.to_bits() as i32;
    (if i < 0 { i32::MIN.wrapping_sub(i) } else { i }) as i64
}

fn ulp_diff(a: f32, b: f32) -> u64 {
    (ulp_key(a) - ulp_key(b)).unsigned_abs()
}

const MAX_ULP: u64 = 512;

#[test]
fn fast_vexp_within_ulp_bound_over_engine_range() {
    // dense argument grid over the full non-flushed domain, both ISA
    // paths, buffer sizes crossing the lane tails
    for &isa in &[Isa::Scalar, Isa::best()] {
        // buffer sizes on, below, and across the 4/8-lane FMA kernels'
        // boundaries, so every tail/main-loop split is swept
        for n in [5usize, 7, 8, 9, 16, 31, 33] {
            let mut worst = 0u64;
            // 7001 points spanning [-87, 88]
            let mut i = 0usize;
            while i < 7001 {
                let xs: Vec<f32> = (0..n)
                    .map(|j| -87.0 + (i + j).min(7000) as f32 * (175.0 / 7000.0))
                    .collect();
                let mut got = xs.clone();
                kernels::vexp(isa, MathTier::Fast, &mut got);
                for (x, g) in xs.iter().zip(&got) {
                    let want = x.exp();
                    let d = ulp_diff(*g, want);
                    worst = worst.max(d);
                    assert!(
                        d <= MAX_ULP,
                        "vexp fast isa={} x={x}: {g} vs {want} ({d} ulp)",
                        isa.name()
                    );
                }
                i += n;
            }
            println!("vexp fast isa={} n={n}: worst {worst} ulp", isa.name());
        }
    }
}

#[test]
fn fast_vln_within_ulp_bound_over_engine_range() {
    // the engines feed vln sums of scaled exponentials: (0, K] roughly,
    // but pin the whole normal range
    for &isa in &[Isa::Scalar, Isa::best()] {
        let mut rng = Rng::new(4);
        for n in [5usize, 7, 8, 9, 16, 31, 33] {
            for trial in 0..400 {
                let xs: Vec<f32> = (0..n)
                    .map(|_| {
                        // log-uniform over [1e-35, 1e35]
                        let e = rng.uniform_in(-35.0, 35.0);
                        (10.0f64.powf(e)) as f32
                    })
                    .collect();
                let mut got = xs.clone();
                kernels::vln(isa, MathTier::Fast, &mut got);
                for (x, g) in xs.iter().zip(&got) {
                    let want = x.ln();
                    let d = ulp_diff(*g, want);
                    assert!(
                        d <= MAX_ULP || (g - want).abs() <= 1e-6,
                        "vln fast isa={} trial={trial} x={x}: {g} vs {want} ({d} ulp)",
                        isa.name()
                    );
                }
            }
        }
    }
}

#[test]
fn fast_tier_edge_semantics() {
    for &isa in &[Isa::Scalar, Isa::best()] {
        let mut e = vec![
            f32::NEG_INFINITY, // -> 0
            -88.0,             // below the flush line -> 0
            -87.0,             // on the line: kept, tiny but nonzero
            0.0,               // -> exactly 1
            88.5,              // above saturation: finite, no overflow
            f32::INFINITY,     // saturates finite
            f32::NAN,          // -> NaN
            -3.25,             // plain value, sanity
        ];
        kernels::vexp(isa, MathTier::Fast, &mut e);
        assert_eq!(e[0], 0.0, "exp(-inf) isa={}", isa.name());
        assert_eq!(e[1], 0.0, "exp flush isa={}", isa.name());
        assert!(e[2] > 0.0 && e[2].is_finite(), "exp(-87) isa={}", isa.name());
        assert_eq!(e[3], 1.0, "exp(0) isa={}", isa.name());
        assert!(e[4].is_finite() && e[4] > 1e37, "exp saturation isa={}", isa.name());
        assert!(e[5].is_finite(), "exp(+inf) saturates isa={}", isa.name());
        assert!(e[6].is_nan(), "exp(NaN) isa={}", isa.name());
        assert!((e[7] - (-3.25f32).exp()).abs() < 1e-6, "exp(-3.25) isa={}", isa.name());

        let mut l = vec![
            0.0f32,        // -> -inf
            -0.0,          // -> -inf
            -1.0,          // -> NaN
            f32::NAN,      // -> NaN
            f32::INFINITY, // -> finite (~2^128 in log space)
            1.0,           // -> exactly 0
            0.125,         // power of two: mantissa path exact
        ];
        kernels::vln(isa, MathTier::Fast, &mut l);
        assert_eq!(l[0], f32::NEG_INFINITY, "ln(0) isa={}", isa.name());
        assert_eq!(l[1], f32::NEG_INFINITY, "ln(-0) isa={}", isa.name());
        assert!(l[2].is_nan(), "ln(-1) isa={}", isa.name());
        assert!(l[3].is_nan(), "ln(NaN) isa={}", isa.name());
        assert!(l[4].is_finite() && l[4] > 88.0, "ln(+inf) isa={}", isa.name());
        assert_eq!(l[5], 0.0, "ln(1) isa={}", isa.name());
        assert!((l[6] - 0.125f32.ln()).abs() < 1e-6, "ln(0.125) isa={}", isa.name());
    }
}

#[test]
fn fast_tier_bit_identical_across_isa_and_call_shapes() {
    let isa = Isa::best();
    let mut rng = Rng::new(17);
    for trial in 0..60 {
        let n = 1 + rng.below(70);
        let mut xs: Vec<f32> = (0..n)
            .map(|_| rng.uniform_in(-90.0, 90.0) as f32)
            .collect();
        if n > 2 {
            xs[rng.below(n)] = f32::NEG_INFINITY;
            xs[rng.below(n)] = 0.0;
        }
        // vexp: scalar lanes vs SIMD lanes, same bits
        let mut a = xs.clone();
        let mut b = xs.clone();
        kernels::vexp(Isa::Scalar, MathTier::Fast, &mut a);
        kernels::vexp(isa, MathTier::Fast, &mut b);
        for (i, (p, q)) in a.iter().zip(&b).enumerate() {
            assert_eq!(
                p.to_bits(),
                q.to_bits(),
                "vexp fast scalar-vs-simd trial={trial} [{i}] x={}",
                xs[i]
            );
            // ...and the one-off scalar call agrees with the sweep
            assert_eq!(
                MathTier::Fast.exp1(xs[i]).to_bits(),
                p.to_bits(),
                "exp1 vs vexp trial={trial} [{i}]"
            );
        }
        // vln on the (non-negative) exp results
        let mut c = a.clone();
        let mut d = a.clone();
        kernels::vln(Isa::Scalar, MathTier::Fast, &mut c);
        kernels::vln(isa, MathTier::Fast, &mut d);
        for (i, (p, q)) in c.iter().zip(&d).enumerate() {
            assert_eq!(
                p.to_bits(),
                q.to_bits(),
                "vln fast scalar-vs-simd trial={trial} [{i}]"
            );
            assert_eq!(
                MathTier::Fast.ln1(a[i]).to_bits(),
                p.to_bits(),
                "ln1 vs vln trial={trial} [{i}]"
            );
        }
    }
}

#[test]
fn default_tier_is_exact() {
    let _g = TIER_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    // the default must stay the bit-exact tier; skip only if the test
    // environment itself opted in via the env knob
    if std::env::var_os("EINET_KERNELS").is_none() {
        assert_eq!(MathTier::detect(), MathTier::Exact);
    }
}

fn random_batch(family: LeafFamily, bn: usize, nv: usize, rng: &mut Rng) -> Vec<f32> {
    let od = family.obs_dim();
    let mut x = vec![0.0f32; bn * nv * od];
    for v in x.chunks_mut(od) {
        match family {
            LeafFamily::Bernoulli => {
                v[0] = if rng.bernoulli(0.5) { 1.0 } else { 0.0 };
            }
            LeafFamily::Gaussian { .. } => {
                for c in v.iter_mut() {
                    *c = 0.5 + 0.2 * rng.normal() as f32;
                }
            }
            LeafFamily::Categorical { cats } => {
                v[0] = rng.below(cats) as f32;
            }
            LeafFamily::Binomial { trials } => {
                v[0] = rng.below(trials as usize + 1) as f32;
            }
        }
    }
    x
}

/// Forward log-likelihoods through an engine built in the requested
/// tier (plus EM statistics under sum-product).
fn run_tier<E: Engine>(
    fast: bool,
    plan: &LayeredPlan,
    family: LeafFamily,
    params: &EinetParams,
    x: &[f32],
    mask: &[f32],
    bn: usize,
) -> (Vec<f32>, EmStats) {
    kernels::force_fastmath(fast);
    let mut e = E::build(plan.clone(), family, bn);
    kernels::force_fastmath(false);
    let mut logp = vec![0.0f32; bn];
    e.forward(params, x, mask, &mut logp);
    let mut stats = EmStats::zeros_like(params);
    e.backward(params, x, mask, bn, &mut stats);
    (logp, stats)
}

#[test]
fn engine_loglik_drift_under_fast_tier_is_bounded() {
    let _g = TIER_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let bn = 13usize;
    let cases: Vec<(LayeredPlan, LeafFamily)> = vec![
        (
            LayeredPlan::compile(random_binary_trees(10, 3, 3, 1), 4),
            LeafFamily::Bernoulli,
        ),
        (
            LayeredPlan::compile(poon_domingos(3, 4, 1, PdAxes::Both), 3),
            LeafFamily::Gaussian { channels: 1 },
        ),
        (
            LayeredPlan::compile(random_binary_trees(8, 2, 2, 8), 10),
            LeafFamily::Categorical { cats: 4 },
        ),
    ];
    for (ci, (plan, family)) in cases.into_iter().enumerate() {
        let nv = plan.graph.num_vars;
        let mut rng = Rng::new(50 + ci as u64);
        let params = EinetParams::init(&plan, family, 50 + ci as u64);
        let x = random_batch(family, bn, nv, &mut rng);
        let mut mask = vec![1.0f32; nv];
        mask[nv / 2] = 0.0; // marginalization goes through the tier too
        let (ll_exact, st_exact) =
            run_tier::<DenseEngine>(false, &plan, family, &params, &x, &mask, bn);
        let (ll_fast, st_fast) =
            run_tier::<DenseEngine>(true, &plan, family, &params, &x, &mask, bn);
        for (b, (a, f)) in ll_exact.iter().zip(&ll_fast).enumerate() {
            assert!(
                a.is_finite() && f.is_finite(),
                "case {ci} row {b}: non-finite LL ({a} exact, {f} fast)"
            );
            assert!(
                (a - f).abs() < 5e-3 * (1.0 + a.abs()),
                "case {ci} row {b}: fast tier drifted: {a} exact vs {f} fast"
            );
        }
        // EM statistics from a Fast-tier backward stay finite and close
        assert!(st_fast.grad.iter().all(|g| g.is_finite()), "case {ci}: NaN in fast grad");
        assert!(st_fast.sum_p.iter().all(|p| p.is_finite()), "case {ci}: NaN in fast sum_p");
        for (i, (a, f)) in st_exact.sum_p.iter().zip(&st_fast.sum_p).enumerate() {
            assert!(
                (a - f).abs() < 1e-2 * (1.0 + a.abs()),
                "case {ci} sum_p[{i}]: {a} exact vs {f} fast"
            );
        }
        // dense and sparse must still agree with each other *within* the
        // fast tier (the tier is engine-independent)
        let (ll_sparse_fast, _) =
            run_tier::<SparseEngine>(true, &plan, family, &params, &x, &mask, bn);
        for (b, (d, s)) in ll_fast.iter().zip(&ll_sparse_fast).enumerate() {
            assert!(
                (d - s).abs() < 1e-3 * (1.0 + d.abs()),
                "case {ci} row {b}: dense/sparse disagree under fast: {d} vs {s}"
            );
        }
    }
}

#[test]
fn fast_tier_is_recorded_at_lowering_not_at_call_time() {
    let _g = TIER_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    // an engine built under Fast keeps producing Fast-tier numbers after
    // the global knob is restored — the tier is plan state, not ambient
    let plan = LayeredPlan::compile(random_binary_trees(10, 3, 3, 2), 4);
    let family = LeafFamily::Bernoulli;
    let params = EinetParams::init(&plan, family, 9);
    let bn = 7usize;
    let mut rng = Rng::new(9);
    let x = random_batch(family, bn, 10, &mut rng);
    let mask = vec![1.0f32; 10];

    kernels::force_fastmath(true);
    let mut e_fast = DenseEngine::new(plan.clone(), family, bn);
    kernels::force_fastmath(false);

    let mut lp_after = vec![0.0f32; bn];
    e_fast.forward(&params, &x, &mask, &mut lp_after);

    kernels::force_fastmath(true);
    let mut lp_during = vec![0.0f32; bn];
    e_fast.forward(&params, &x, &mask, &mut lp_during);
    kernels::force_fastmath(false);

    assert_eq!(
        lp_after, lp_during,
        "tier must be pinned in the plan, not re-read per forward"
    );
}

#[test]
fn fma_lanes_match_scalar_mul_add_bitwise() {
    // the Fast tier's polynomials now evaluate through FMA — scalar
    // `f32::mul_add` lanes and the SIMD fused-multiply-add lanes are
    // both correctly rounded, so the cross-ISA identity contract
    // survives fusion. Pin it over adversarial inputs: lane-boundary
    // crossing lengths, subnormal-adjacent magnitudes, and the exact
    // powers of two the range reductions pivot on.
    let isa = Isa::best();
    let mut special: Vec<f32> = vec![
        0.0, -0.0, 1.0, -1.0, 0.5, 2.0, std::f32::consts::LN_2,
        -std::f32::consts::LN_2, 87.0, -87.0, 1e-30, 1e30,
    ];
    let mut rng = Rng::new(91);
    for _ in 0..83 {
        special.push(rng.uniform_in(-87.0, 87.0) as f32);
    }
    for hi in 1..special.len() {
        let mut a = special[..hi].to_vec();
        let mut b = a.clone();
        kernels::vexp(Isa::Scalar, MathTier::Fast, &mut a);
        kernels::vexp(isa, MathTier::Fast, &mut b);
        for (i, (p, q)) in a.iter().zip(&b).enumerate() {
            assert_eq!(
                p.to_bits(),
                q.to_bits(),
                "FMA vexp len={hi} [{i}] x={}",
                special[i]
            );
        }
        let mut c: Vec<f32> = special[..hi].iter().map(|x| x.abs() + 0.1).collect();
        let mut d = c.clone();
        kernels::vln(Isa::Scalar, MathTier::Fast, &mut c);
        kernels::vln(isa, MathTier::Fast, &mut d);
        for (i, (p, q)) in c.iter().zip(&d).enumerate() {
            assert_eq!(p.to_bits(), q.to_bits(), "FMA vln len={hi} [{i}]");
        }
    }
}

#[test]
fn batched_leaf_normalizer_is_bit_identical_to_scalar_path_in_both_tiers() {
    // the leaf-layer emission pass refreshes a whole region's
    // log-normalizers through ONE vectorized sweep
    // (`LeafFamily::log_norm_const_batch`); per component it must
    // reproduce the scalar `log_norm_const_tier` value bit-for-bit in
    // BOTH tiers — the dense and fused engines rely on this for their
    // own bit-identity contract.
    let families = [
        LeafFamily::Bernoulli,
        LeafFamily::Gaussian { channels: 1 },
        LeafFamily::Gaussian { channels: 3 },
        LeafFamily::Categorical { cats: 5 },
        LeafFamily::Binomial { trials: 7 },
    ];
    let mut rng = Rng::new(77);
    for family in families {
        let s_dim = family.stat_dim();
        for n in [1usize, 3, 8, 17] {
            let mut thetas = vec![0.0f32; n * s_dim];
            for i in 0..n {
                let th = &mut thetas[i * s_dim..(i + 1) * s_dim];
                match family {
                    LeafFamily::Gaussian { channels } => {
                        for j in 0..channels {
                            th[j] = rng.uniform_in(-2.0, 2.0) as f32;
                            th[channels + j] = rng.uniform_in(-5.0, -0.1) as f32;
                        }
                    }
                    _ => {
                        for t in th.iter_mut() {
                            *t = rng.uniform_in(-4.0, 4.0) as f32;
                        }
                    }
                }
            }
            // occasionally hit the softplus large-argument guard
            if s_dim == 1 && n > 2 {
                thetas[0] = 25.0;
            }
            for math in [MathTier::Exact, MathTier::Fast] {
                for isa in [Isa::Scalar, Isa::best()] {
                    let mut out = vec![0.0f32; n];
                    let mut stage = Vec::new();
                    family.log_norm_const_batch(&thetas, &mut out, isa, math, &mut stage);
                    for i in 0..n {
                        let th = &thetas[i * s_dim..(i + 1) * s_dim];
                        let want = family.log_norm_const_tier(th, math);
                        assert_eq!(
                            out[i].to_bits(),
                            want.to_bits(),
                            "family={family:?} {math:?} isa={} n={n} comp={i}: \
                             batched {} vs scalar {want}",
                            isa.name(),
                            out[i]
                        );
                    }
                }
            }
        }
    }
}
