//! Statistical acceptance suite for the batched `SamplePlan` sampler:
//! empirical frequencies from `sample_batch` / `decode_batch` must match
//! the exact densities the forward pass computes — per `LeafFamily`, for
//! BOTH engines, unconditionally and under evidence masks. Discrete
//! families get a Pearson chi-square test against enumerated state
//! probabilities; the Gaussian family gets a KS test of a sampled
//! marginal against the numerically integrated marginal CDF. Every test
//! is seeded and the significance thresholds are generous (alpha ~ 1e-4)
//! so the suite is deterministic in CI.

use einet::infer::{conditional_log_prob, inpaint};
use einet::structure::{poon_domingos, random_binary_trees, PdAxes};
use einet::util::rng::Rng;
use einet::util::stats::{chi_square_critical, chi_square_stat, ks_distance};
use einet::{
    DecodeMode, DenseEngine, EinetParams, Engine, LayeredPlan, LeafFamily,
    SparseEngine,
};

/// Generous one-sided normal quantile: alpha ~ 1.2e-4.
const Z_CRIT: f64 = 3.7;

/// Enumerate every joint state of `nv` variables with `m` values each,
/// little-endian (digit d of state s is `(s / m^d) % m`).
fn all_states(m: usize, nv: usize) -> (usize, Vec<f32>) {
    let states = m.pow(nv as u32);
    let mut x = vec![0.0f32; states * nv];
    for s in 0..states {
        let mut t = s;
        for d in 0..nv {
            x[s * nv + d] = (t % m) as f32;
            t /= m;
        }
    }
    (states, x)
}

fn state_index(row: &[f32], m: usize) -> usize {
    let mut idx = 0usize;
    let mut mul = 1usize;
    for &v in row {
        idx += (v as usize) * mul;
        mul *= m;
    }
    idx
}

/// Chi-square test: unconditional `sample_batch` frequencies against the
/// exact enumerated density, for any discrete family with `m` values per
/// variable.
fn discrete_unconditional<E: Engine>(
    plan: LayeredPlan,
    family: LeafFamily,
    m: usize,
    seed: u64,
    label: &str,
) {
    let nv = plan.graph.num_vars;
    let params = EinetParams::init(&plan, family, seed);
    let (states, x) = all_states(m, nv);
    let mut engine = E::build(plan, family, 256.max(states));
    let mask = vec![1.0f32; nv];
    let mut logp = vec![0.0f32; states];
    engine.forward(&params, &x, &mask, &mut logp);
    let probs: Vec<f64> = logp.iter().map(|&l| (l as f64).exp()).collect();
    let total: f64 = probs.iter().sum();
    assert!((total - 1.0).abs() < 1e-3, "{label}: density sums to {total}");

    let n = 25_000;
    let mut rng = Rng::new(seed + 1000);
    let samples = engine.sample_batch(&params, n, &mut rng, DecodeMode::Sample);
    let mut counts = vec![0usize; states];
    for s in 0..n {
        counts[state_index(&samples[s * nv..(s + 1) * nv], m)] += 1;
    }
    let chi2 = chi_square_stat(&counts, &probs, n);
    let crit = chi_square_critical((states - 1) as f64, Z_CRIT);
    assert!(
        chi2 < crit,
        "{label}: chi2 {chi2:.2} exceeds critical {crit:.2} (df {})",
        states - 1
    );
}

fn rat_plan(nv: usize, seed: u64) -> LayeredPlan {
    LayeredPlan::compile(random_binary_trees(nv, 2, 2, seed), 3)
}

#[test]
fn unconditional_bernoulli_matches_density_dense() {
    discrete_unconditional::<DenseEngine>(
        rat_plan(4, 0),
        LeafFamily::Bernoulli,
        2,
        10,
        "dense/bernoulli",
    );
}

#[test]
fn unconditional_bernoulli_matches_density_sparse() {
    discrete_unconditional::<SparseEngine>(
        rat_plan(4, 0),
        LeafFamily::Bernoulli,
        2,
        10,
        "sparse/bernoulli",
    );
}

#[test]
fn unconditional_categorical_matches_density_dense() {
    discrete_unconditional::<DenseEngine>(
        rat_plan(2, 1),
        LeafFamily::Categorical { cats: 3 },
        3,
        11,
        "dense/categorical",
    );
}

#[test]
fn unconditional_categorical_matches_density_sparse() {
    discrete_unconditional::<SparseEngine>(
        rat_plan(2, 1),
        LeafFamily::Categorical { cats: 3 },
        3,
        11,
        "sparse/categorical",
    );
}

#[test]
fn unconditional_binomial_matches_density_dense() {
    discrete_unconditional::<DenseEngine>(
        rat_plan(2, 2),
        LeafFamily::Binomial { trials: 2 },
        3,
        12,
        "dense/binomial",
    );
}

#[test]
fn unconditional_binomial_matches_density_sparse() {
    discrete_unconditional::<SparseEngine>(
        rat_plan(2, 2),
        LeafFamily::Binomial { trials: 2 },
        3,
        12,
        "sparse/binomial",
    );
}

#[test]
fn pd_mixing_structure_matches_density_both_engines() {
    // Poon–Domingos with both axes ⇒ mixing layers ⇒ the sampler's
    // posterior-weighted partition choice is exercised
    let plan = LayeredPlan::compile(poon_domingos(2, 3, 1, PdAxes::Both), 3);
    discrete_unconditional::<DenseEngine>(
        plan.clone(),
        LeafFamily::Bernoulli,
        2,
        13,
        "dense/pd",
    );
    discrete_unconditional::<SparseEngine>(plan, LeafFamily::Bernoulli, 2, 13, "sparse/pd");
}

/// KS test of the sampled Gaussian marginal of variable 0 against its
/// numerically integrated CDF (the forward pass under a single-variable
/// mask IS the marginal density).
fn gaussian_marginal_ks<E: Engine>(seed: u64, label: &str) {
    let nv = 4;
    let family = LeafFamily::Gaussian { channels: 1 };
    let plan = rat_plan(nv, seed);
    let params = EinetParams::init(&plan, family, seed);
    let grid_n = 800usize;
    let (lo, hi) = (-1.5f32, 3.0f32);
    let mut engine = E::build(plan, family, grid_n.max(256));
    let mut mask = vec![0.0f32; nv];
    mask[0] = 1.0;
    let dx = ((hi - lo) / grid_n as f32) as f64;
    let mut xg = vec![0.0f32; grid_n * nv];
    for i in 0..grid_n {
        xg[i * nv] = lo + (i as f32 + 0.5) * (hi - lo) / grid_n as f32;
    }
    let mut logp = vec![0.0f32; grid_n];
    engine.forward(&params, &xg, &mask, &mut logp);
    let mut cdf_grid = vec![0.0f64; grid_n];
    let mut acc = 0.0f64;
    for i in 0..grid_n {
        acc += (logp[i] as f64).exp() * dx;
        cdf_grid[i] = acc;
    }
    assert!(
        (acc - 1.0).abs() < 0.02,
        "{label}: marginal integrates to {acc}"
    );

    let n = 20_000;
    let mut rng = Rng::new(seed + 2000);
    let samples = engine.sample_batch(&params, n, &mut rng, DecodeMode::Sample);
    let mut v0: Vec<f64> = (0..n).map(|s| samples[s * nv] as f64).collect();
    v0.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let cdf = |x: f64| -> f64 {
        if x <= lo as f64 {
            0.0
        } else if x >= hi as f64 {
            1.0
        } else {
            let pos = ((x - lo as f64) / dx) as usize;
            cdf_grid[pos.min(grid_n - 1)]
        }
    };
    let d = ks_distance(&v0, cdf);
    // KS critical at alpha=1e-4 is ~1.95/sqrt(n) ≈ 0.014; allow grid
    // integration error on top
    assert!(d < 0.03, "{label}: KS distance {d:.4}");
}

#[test]
fn gaussian_marginal_matches_cdf_dense() {
    gaussian_marginal_ks::<DenseEngine>(20, "dense/gaussian");
}

#[test]
fn gaussian_marginal_matches_cdf_sparse() {
    gaussian_marginal_ks::<SparseEngine>(20, "sparse/gaussian");
}

/// Conditional sampling: with evidence clamped, `inpaint` (one batched
/// forward + one batched decode per chunk) must draw the query variables
/// from the exact conditional p(x_q | x_e).
fn conditional_matches_exact<E: Engine>(seed: u64, label: &str) {
    let nv = 5;
    let family = LeafFamily::Bernoulli;
    let plan = rat_plan(nv, seed);
    let params = EinetParams::init(&plan, family, seed);
    let mut engine = E::build(plan, family, 256);
    // evidence: x0 = 1, x1 = 0; query: x2, x3, x4 (8 states)
    let mut emask = vec![0.0f32; nv];
    emask[0] = 1.0;
    emask[1] = 1.0;
    let mut qmask = vec![0.0f32; nv];
    qmask[2] = 1.0;
    qmask[3] = 1.0;
    qmask[4] = 1.0;
    let mut probs = vec![0.0f64; 8];
    for s in 0..8usize {
        let mut x = vec![0.0f32; nv];
        x[0] = 1.0;
        x[2] = (s & 1) as f32;
        x[3] = ((s >> 1) & 1) as f32;
        x[4] = ((s >> 2) & 1) as f32;
        let mut lp = vec![0.0f32; 1];
        conditional_log_prob(&mut engine, &params, &x, &qmask, &emask, &mut lp);
        probs[s] = (lp[0] as f64).exp();
    }
    let total: f64 = probs.iter().sum();
    assert!(
        (total - 1.0).abs() < 1e-3,
        "{label}: conditional sums to {total}"
    );

    let n = 16_000;
    let mut base = vec![0.0f32; nv];
    base[0] = 1.0;
    let xs = base.repeat(n);
    let mut rng = Rng::new(seed + 3000);
    let out = inpaint(&mut engine, &params, &xs, &emask, n, DecodeMode::Sample, &mut rng);
    let mut counts = vec![0usize; 8];
    for b in 0..n {
        // evidence untouched, completions binary
        assert_eq!(out[b * nv], 1.0, "{label}: evidence x0 resampled");
        assert_eq!(out[b * nv + 1], 0.0, "{label}: evidence x1 resampled");
        let mut s = 0usize;
        for q in 0..3 {
            let v = out[b * nv + 2 + q];
            assert!(v == 0.0 || v == 1.0, "{label}: non-binary completion");
            if v > 0.5 {
                s |= 1 << q;
            }
        }
        counts[s] += 1;
    }
    let chi2 = chi_square_stat(&counts, &probs, n);
    let crit = chi_square_critical(7.0, Z_CRIT);
    assert!(
        chi2 < crit,
        "{label}: conditional chi2 {chi2:.2} exceeds critical {crit:.2}"
    );
}

#[test]
fn conditional_sampling_matches_exact_dense() {
    conditional_matches_exact::<DenseEngine>(30, "dense/conditional");
}

#[test]
fn conditional_sampling_matches_exact_sparse() {
    conditional_matches_exact::<SparseEngine>(30, "sparse/conditional");
}

#[test]
fn argmax_batched_sampling_is_deterministic() {
    // Argmax mode touches no RNG: every batch row must be identical, and
    // two independent runs must agree bitwise
    let plan = rat_plan(6, 4);
    let family = LeafFamily::Bernoulli;
    let params = EinetParams::init(&plan, family, 4);
    let mut engine = DenseEngine::new(plan, family, 32);
    let mut rng_a = Rng::new(1);
    let a = engine.sample_batch(&params, 8, &mut rng_a, DecodeMode::Argmax);
    let mut rng_b = Rng::new(99);
    let b = engine.sample_batch(&params, 8, &mut rng_b, DecodeMode::Argmax);
    assert_eq!(a, b, "Argmax sampling depends on the RNG");
    for s in 1..8 {
        assert_eq!(
            &a[..6],
            &a[s * 6..(s + 1) * 6],
            "Argmax rows differ within a batch"
        );
    }
}
