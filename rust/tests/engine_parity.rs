//! Dense/sparse engine parity as ONE generic harness over the `Engine`
//! trait: for every `LeafFamily` variant, over both RAT (tree) and
//! Poon–Domingos (mixing-layer) structures, under full-evidence and
//! random marginalization masks, the two engines must produce identical
//! log-likelihoods, identical flat EM statistics, and identical
//! marginals — they are two layouts of the same model (the paper's
//! Table 1 premise).

use einet::engine::exec::ExecPlan;
use einet::structure::{poon_domingos, random_binary_trees, PdAxes};
use einet::util::rng::Rng;
use einet::{
    DenseEngine, EinetParams, EmStats, Engine, LayeredPlan, LeafFamily,
    SparseEngine,
};

/// Draw a batch of valid observations for the family.
fn random_batch(family: LeafFamily, bn: usize, nv: usize, rng: &mut Rng) -> Vec<f32> {
    let od = family.obs_dim();
    let mut x = vec![0.0f32; bn * nv * od];
    for v in x.chunks_mut(od) {
        match family {
            LeafFamily::Bernoulli => {
                v[0] = if rng.bernoulli(0.5) { 1.0 } else { 0.0 };
            }
            LeafFamily::Gaussian { .. } => {
                for c in v.iter_mut() {
                    *c = 0.5 + 0.2 * rng.normal() as f32;
                }
            }
            LeafFamily::Categorical { cats } => {
                v[0] = rng.below(cats) as f32;
            }
            LeafFamily::Binomial { trials } => {
                v[0] = rng.below(trials as usize + 1) as f32;
            }
        }
    }
    x
}

/// A random marginalization mask that keeps at least one variable.
fn random_mask(nv: usize, rng: &mut Rng) -> Vec<f32> {
    loop {
        let mask: Vec<f32> = (0..nv)
            .map(|_| if rng.bernoulli(0.6) { 1.0 } else { 0.0 })
            .collect();
        if mask.iter().any(|&m| m != 0.0) {
            return mask;
        }
    }
}

/// The generic harness: run forward + backward through any engine.
fn run_engine<E: Engine>(
    plan: &LayeredPlan,
    family: LeafFamily,
    params: &EinetParams,
    x: &[f32],
    mask: &[f32],
    bn: usize,
) -> (Vec<f32>, EmStats) {
    let mut engine = E::build(plan.clone(), family, bn);
    let mut logp = vec![0.0f32; bn];
    engine.forward(params, x, mask, &mut logp);
    let mut stats = EmStats::zeros_like(params);
    engine.backward(params, x, mask, bn, &mut stats);
    (logp, stats)
}

fn assert_stats_close(a: &EmStats, b: &EmStats, ctx: &str) {
    assert_eq!(a.count, b.count, "{ctx}: count");
    assert!(
        (a.loglik - b.loglik).abs() < 1e-3 * (1.0 + a.loglik.abs()),
        "{ctx}: loglik {} vs {}",
        a.loglik,
        b.loglik
    );
    for (i, (x, y)) in a.grad.iter().zip(&b.grad).enumerate() {
        assert!(
            (x - y).abs() < 3e-3 * (1.0 + x.abs()),
            "{ctx}: grad[{i}] {x} vs {y}"
        );
    }
    for (i, (x, y)) in a.sum_p.iter().zip(&b.sum_p).enumerate() {
        assert!(
            (x - y).abs() < 3e-3 * (1.0 + x.abs()),
            "{ctx}: sum_p[{i}] {x} vs {y}"
        );
    }
}

fn parity_case(plan: &LayeredPlan, family: LeafFamily, seed: u64, label: &str) {
    let nv = plan.graph.num_vars;
    let bn = 8;
    let mut rng = Rng::new(seed);
    let params = EinetParams::init(plan, family, seed);
    let x = random_batch(family, bn, nv, &mut rng);
    let full = vec![1.0f32; nv];
    for (mi, mask) in [full, random_mask(nv, &mut rng), random_mask(nv, &mut rng)]
        .into_iter()
        .enumerate()
    {
        let ctx = format!("{label} family={family:?} mask#{mi}");
        let (lp_d, st_d) =
            run_engine::<DenseEngine>(plan, family, &params, &x, &mask, bn);
        let (lp_s, st_s) =
            run_engine::<SparseEngine>(plan, family, &params, &x, &mask, bn);
        for (b, (a, s)) in lp_d.iter().zip(&lp_s).enumerate() {
            assert!(a.is_finite(), "{ctx}: dense logp[{b}] not finite");
            assert!(
                (a - s).abs() < 1e-3 * (1.0 + a.abs()),
                "{ctx}: logp[{b}] dense {a} vs sparse {s}"
            );
        }
        assert_stats_close(&st_d, &st_s, &ctx);
    }
}

fn all_families() -> Vec<LeafFamily> {
    vec![
        LeafFamily::Bernoulli,
        LeafFamily::Gaussian { channels: 1 },
        LeafFamily::Gaussian { channels: 3 },
        LeafFamily::Categorical { cats: 4 },
        LeafFamily::Binomial { trials: 6 },
    ]
}

#[test]
fn parity_all_families_rat_structure() {
    for (i, family) in all_families().into_iter().enumerate() {
        let plan = LayeredPlan::compile(random_binary_trees(10, 3, 3, i as u64), 4);
        parity_case(&plan, family, 10 + i as u64, "rat");
    }
}

#[test]
fn parity_all_families_pd_mixing_structure() {
    // Poon–Domingos with both axes ⇒ multi-partition regions ⇒ mixing
    // layers on several levels — the structurally hard case
    for (i, family) in all_families().into_iter().enumerate() {
        let plan = LayeredPlan::compile(poon_domingos(3, 4, 1, PdAxes::Both), 3);
        parity_case(&plan, family, 20 + i as u64, "pd");
    }
}

#[test]
fn marginals_are_consistent_across_engines_and_masks() {
    // p(x_e) computed by either engine under nested masks: more
    // marginalization can only increase the log-likelihood mass
    let plan = LayeredPlan::compile(random_binary_trees(9, 2, 2, 3), 3);
    let family = LeafFamily::Bernoulli;
    let params = EinetParams::init(&plan, family, 3);
    let mut rng = Rng::new(99);
    let bn = 4;
    let x = random_batch(family, bn, 9, &mut rng);
    let mut dense = DenseEngine::new(plan.clone(), family, bn);
    let mut sparse = SparseEngine::new(plan, family, bn);
    let full = vec![1.0f32; 9];
    let mut partial = full.clone();
    partial[2] = 0.0;
    partial[5] = 0.0;
    let mut lp_full = vec![0.0f32; bn];
    let mut lp_part_d = vec![0.0f32; bn];
    let mut lp_part_s = vec![0.0f32; bn];
    dense.forward(&params, &x, &full, &mut lp_full);
    dense.forward(&params, &x, &partial, &mut lp_part_d);
    sparse.forward(&params, &x, &partial, &mut lp_part_s);
    for b in 0..bn {
        assert!((lp_part_d[b] - lp_part_s[b]).abs() < 1e-4);
        assert!(
            lp_part_d[b] >= lp_full[b] - 1e-4,
            "marginal smaller than joint"
        );
    }
}

#[test]
fn kernel_paths_agree_on_randomized_operands() {
    // proptest-style randomized-operand check: across random shapes
    // (k, ko, block width) and random log-domain operands — including
    // the 0-probability (-inf) edge — the scalar and SIMD einsum kernels
    // must agree bit-for-bit, and the blocked layout must reproduce the
    // per-row dot4/max4 reduction exactly. 120 random cases per run,
    // deterministic seeds so failures replay.
    use einet::engine::exec::Semiring;
    use einet::engine::kernels::{self, Isa};
    let isa = Isa::best();
    for case in 0..120u64 {
        let mut rng = Rng::new(0xC0FFEE + case);
        let k = 1 + rng.below(12);
        let ko = 1 + rng.below(k);
        let bb = 1 + rng.below(24);
        let k2 = k * k;
        let mut w: Vec<f32> = (0..ko * k2)
            .map(|_| rng.uniform_in(0.0, 1.0) as f32)
            .collect();
        if !w.is_empty() {
            let zi = rng.below(w.len());
            w[zi] = 0.0; // exact-zero weights occur after EM steps
        }
        // children in log-domain, occasionally -inf (zero probability)
        let mut logn: Vec<f32> = (0..k * bb)
            .map(|_| rng.uniform_in(-40.0, 0.0) as f32)
            .collect();
        let lognp: Vec<f32> = (0..k * bb)
            .map(|_| rng.uniform_in(-40.0, 0.0) as f32)
            .collect();
        if rng.bernoulli(0.3) {
            logn[rng.below(logn.len())] = f32::NEG_INFINITY;
        }
        // scale per-lane like the engines do (max-subtracted exps)
        let mut en_t = vec![0.0f32; k * bb];
        let mut enp_t = vec![0.0f32; k * bb];
        for lane in 0..bb {
            let mut a = f32::NEG_INFINITY;
            let mut ap = f32::NEG_INFINITY;
            for kk in 0..k {
                a = a.max(logn[kk * bb + lane]);
                ap = ap.max(lognp[kk * bb + lane]);
            }
            for kk in 0..k {
                en_t[kk * bb + lane] = (logn[kk * bb + lane] - a).exp();
                enp_t[kk * bb + lane] = (lognp[kk * bb + lane] - ap).exp();
            }
        }
        let mut pt_s = vec![0.0f32; k2 * bb];
        let mut pt_v = vec![0.0f32; k2 * bb];
        kernels::outer_block(Isa::Scalar, &en_t, &enp_t, k, bb, &mut pt_s);
        kernels::outer_block(isa, &en_t, &enp_t, k, bb, &mut pt_v);
        let as_bits = |xs: &[f32]| xs.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        assert_eq!(as_bits(&pt_s), as_bits(&pt_v), "case {case}: outer_block");
        for sr in [Semiring::SumProduct, Semiring::MaxProduct] {
            let mut acc_s = vec![0.0f32; ko * bb];
            let mut acc_v = vec![0.0f32; ko * bb];
            kernels::einsum_block(Isa::Scalar, sr, &w, &pt_s, k2, ko, bb, &mut acc_s);
            kernels::einsum_block(isa, sr, &w, &pt_s, k2, ko, bb, &mut acc_v);
            assert_eq!(
                as_bits(&acc_s),
                as_bits(&acc_v),
                "case {case} {sr:?}: scalar vs SIMD einsum_block"
            );
            // per-row reference: the pre-kernel engine reduction
            for lane in 0..bb {
                let mut prow = vec![0.0f32; k2];
                for ii in 0..k {
                    for jj in 0..k {
                        prow[ii * k + jj] =
                            en_t[ii * bb + lane] * enp_t[jj * bb + lane];
                    }
                }
                for kout in 0..ko {
                    let wrow = &w[kout * k2..(kout + 1) * k2];
                    let want = match sr {
                        Semiring::SumProduct => kernels::dot4(Isa::Scalar, wrow, &prow),
                        Semiring::MaxProduct => kernels::max4(Isa::Scalar, wrow, &prow),
                    };
                    assert_eq!(
                        want.to_bits(),
                        acc_s[kout * bb + lane].to_bits(),
                        "case {case} {sr:?} lane={lane} kout={kout}: blocked vs per-row"
                    );
                }
            }
        }
    }
}

#[test]
fn exec_plan_is_engine_shared() {
    // both engines lower the same plan to the same step program shape
    let plan = LayeredPlan::compile(poon_domingos(2, 4, 1, PdAxes::Both), 3);
    let ep_a = ExecPlan::lower(plan.clone(), LeafFamily::Bernoulli, 8);
    let ep_b = ExecPlan::lower(plan, LeafFamily::Bernoulli, 8);
    assert_eq!(ep_a.steps.len(), ep_b.steps.len());
    assert_eq!(ep_a.arena_len, ep_b.arena_len);
    assert_eq!(ep_a.scratch_len, ep_b.scratch_len);
    assert_eq!(ep_a.layout, ep_b.layout);
}
