//! Corruption and validation contract of the on-disk data loaders.
//!
//! The DEBD `.data` parser and the `.eimg` labeled-image codec ingest
//! files that arrive from disk, not from this process, so — mirroring
//! the checkpoint codec's corruption suite — every malformation must
//! surface as a typed error naming the source, never a panic or a
//! silently wrong dataset. Also pinned here:
//!
//! * `save_labeled` / `load_labeled` round-trip the committed fixture
//!   format bit-for-bit (quantization aside);
//! * the committed benchmark fixtures load and pass family validation;
//! * `validate_family` rejects arity mismatches (categorical values
//!   under Bernoulli leaves, rows not divisible by the observation
//!   dim) at load time instead of inside a leaf kernel.

use std::path::{Path, PathBuf};

use einet::data::{debd, images, Split};
use einet::LeafFamily;

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("einet_data_{}_{name}", std::process::id()))
}

fn fixtures_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("fixtures")
}

/// Assert `r` is an error whose message contains `needle` — the typed
/// message is the API surface operators grep for, so it is pinned.
fn assert_err_contains<T: std::fmt::Debug>(
    r: einet::util::error::Result<T>,
    needle: &str,
    what: &str,
) {
    let e = match r {
        Ok(v) => panic!("{what}: expected an error containing {needle:?}, got Ok({v:?})"),
        Err(e) => e.to_string(),
    };
    assert!(
        e.contains(needle),
        "{what}: error {e:?} does not mention {needle:?}"
    );
}

// ---------------------------------------------------------------------------
// DEBD .data parser
// ---------------------------------------------------------------------------

#[test]
fn debd_parse_accepts_the_canonical_format() {
    // trailing newline optional, blank lines skipped, spaces tolerated
    let s = debd::parse_split("1,0,1\n0, 1 ,0\n\n1,1,1", "t").unwrap();
    assert_eq!(s.n, 3);
    assert_eq!(s.row_len, 3);
    assert_eq!(s.row(1), &[0.0, 1.0, 0.0]);
}

#[test]
fn debd_parse_rejects_non_integer_tokens_with_line_numbers() {
    for bad in ["1,0\nx,1\n", "1,0\n0.5,1\n", "1,0\n-1,1\n", "1,0\n,1\n"] {
        let r = debd::parse_split(bad, "corrupt.data");
        assert_err_contains(r, "is not a non-negative integer", bad);
        // the offending line is named (line 2 in every case above)
        assert_err_contains(
            debd::parse_split(bad, "corrupt.data"),
            "corrupt.data:2",
            bad,
        );
    }
}

#[test]
fn debd_parse_rejects_ragged_rows() {
    let r = debd::parse_split("1,0,1\n0,1\n", "ragged.data");
    assert_err_contains(r, "row has 2 values, expected 3", "ragged row");
}

#[test]
fn debd_parse_rejects_empty_files() {
    for empty in ["", "\n\n  \n"] {
        assert_err_contains(debd::parse_split(empty, "void.data"), "no data rows", "empty");
    }
}

#[test]
fn debd_missing_split_file_is_a_typed_error_with_the_path() {
    let r = debd::load_split_file(&tmp("does_not_exist.data"));
    assert_err_contains(r, "cannot read DEBD split", "missing file");
}

#[test]
fn debd_load_dir_rejects_disagreeing_splits() {
    let dir = tmp("debd_disagree");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("toy.train.data"), "1,0,1\n0,1,0\n").unwrap();
    std::fs::write(dir.join("toy.valid.data"), "1,0,1\n").unwrap();
    std::fs::write(dir.join("toy.test.data"), "1,0\n").unwrap(); // 2 vars, not 3
    let r = debd::load_dir(&dir, "toy");
    assert_err_contains(r, "disagree on variable count", "ragged dataset");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn debd_load_dir_round_trips_a_written_dataset() {
    let dir = tmp("debd_ok");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("toy.train.data"), "1,0,1\n0,1,0\n1,1,0\n").unwrap();
    std::fs::write(dir.join("toy.valid.data"), "0,0,1\n").unwrap();
    std::fs::write(dir.join("toy.test.data"), "1,0,0\n").unwrap();
    let ds = debd::load_dir(&dir, "toy").unwrap();
    assert_eq!(ds.num_vars, 3);
    assert_eq!((ds.train.n, ds.valid.n, ds.test.n), (3, 1, 1));
    assert_eq!(ds.train.row(2), &[1.0, 1.0, 0.0]);
    ds.validate_family(LeafFamily::Bernoulli).unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// .eimg labeled-image codec
// ---------------------------------------------------------------------------

/// A tiny valid in-memory .eimg: 2 images of 2x2x1, 2 classes.
fn valid_eimg() -> Vec<u8> {
    let mut buf = Vec::new();
    buf.extend_from_slice(images::EIMG_MAGIC);
    for v in [2u32, 2, 2, 1, 2] {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    buf.extend_from_slice(&[0u8, 1]); // labels
    buf.extend_from_slice(&[0, 255, 128, 64, 255, 0, 0, 32]); // pixels
    buf
}

#[test]
fn eimg_parses_a_valid_buffer() {
    let li = images::parse_labeled(&valid_eimg(), "t").unwrap();
    assert_eq!((li.split.n, li.h, li.w, li.channels, li.classes), (2, 2, 2, 1, 2));
    assert_eq!(li.labels, vec![0, 1]);
    assert_eq!(li.split.row_len, 4);
    assert!((li.split.data[1] - 1.0).abs() < 1e-6); // 255 -> 1.0
    assert!((li.split.data[3] - 64.0 / 255.0).abs() < 1e-6);
}

#[test]
fn eimg_rejects_short_headers() {
    for cut in [0usize, 3, 4, 23] {
        let r = images::parse_labeled(&valid_eimg()[..cut], "short");
        assert_err_contains(r, "truncated header", &format!("cut at {cut}"));
    }
}

#[test]
fn eimg_rejects_bad_magic() {
    let mut b = valid_eimg();
    b[0] = b'X';
    assert_err_contains(
        images::parse_labeled(&b, "magic"),
        "not an .eimg file",
        "bad magic",
    );
}

#[test]
fn eimg_rejects_degenerate_shapes_and_zero_classes() {
    // zero out each header field in turn: n, h, w, channels -> degenerate
    for field in 0..4usize {
        let mut b = valid_eimg();
        b[4 + field * 4..4 + (field + 1) * 4].copy_from_slice(&0u32.to_le_bytes());
        assert_err_contains(
            images::parse_labeled(&b, "shape"),
            "degenerate shape",
            &format!("field {field}"),
        );
    }
    let mut b = valid_eimg();
    b[4 + 4 * 4..4 + 5 * 4].copy_from_slice(&0u32.to_le_bytes());
    assert_err_contains(
        images::parse_labeled(&b, "classes"),
        "class count must be >= 1",
        "zero classes",
    );
}

#[test]
fn eimg_rejects_truncated_and_oversized_payloads() {
    let full = valid_eimg();
    // every truncation point inside the body, and one trailing byte
    for cut in 24..full.len() {
        let r = images::parse_labeled(&full[..cut], "trunc");
        assert_err_contains(r, "payload carries", &format!("cut at {cut}"));
    }
    let mut long = full.clone();
    long.push(0);
    assert_err_contains(
        images::parse_labeled(&long, "long"),
        "payload carries",
        "trailing byte",
    );
}

#[test]
fn eimg_rejects_out_of_range_labels() {
    let mut b = valid_eimg();
    b[24 + 1] = 2; // second label == classes
    assert_err_contains(
        images::parse_labeled(&b, "label"),
        "outside the declared 2 classes",
        "label overflow",
    );
}

#[test]
fn eimg_rejects_overflowing_shape_headers() {
    // h = w = channels = u32::MAX: h*w*channels overflows usize (64-bit:
    // the product of three 2^32-1 factors), n*row_len certainly does
    let mut b = valid_eimg();
    for field in [1usize, 2, 3] {
        b[4 + field * 4..4 + (field + 1) * 4].copy_from_slice(&u32::MAX.to_le_bytes());
    }
    let e = images::parse_labeled(&b, "huge").unwrap_err().to_string();
    assert!(
        e.contains("overflows"),
        "overflowing shape must be caught: {e}"
    );
}

#[test]
fn eimg_missing_file_is_a_typed_error_with_the_path() {
    let r = images::load_labeled(&tmp("does_not_exist.eimg"));
    assert_err_contains(r, "cannot read image file", "missing file");
}

#[test]
fn eimg_save_load_round_trip() {
    let split = Split {
        n: 3,
        row_len: 4,
        data: vec![
            0.0, 1.0, 0.5, 0.25, //
            1.0, 0.0, 0.75, 0.1, //
            0.2, 0.9, 0.0, 1.0,
        ],
    };
    let labels = vec![0u8, 2, 1];
    let path = tmp("roundtrip.eimg");
    images::save_labeled(&path, &split, &labels, 2, 2, 1, 3).unwrap();
    let li = images::load_labeled(&path).unwrap();
    assert_eq!((li.split.n, li.h, li.w, li.channels, li.classes), (3, 2, 2, 1, 3));
    assert_eq!(li.labels, labels);
    // round-trip is exact up to the byte quantization the writer applies
    for (a, b) in split.data.iter().zip(&li.split.data) {
        assert!(
            (a - b).abs() <= 0.5 / 255.0 + 1e-6,
            "quantization drift: wrote {a}, read {b}"
        );
    }
    let _ = std::fs::remove_file(&path);
}

#[test]
fn eimg_writer_validates_before_writing() {
    let split = Split {
        n: 2,
        row_len: 4,
        data: vec![0.0; 8],
    };
    let path = tmp("never_written.eimg");
    // shape mismatch
    assert!(images::save_labeled(&path, &split, &[0, 0], 3, 3, 1, 2).is_err());
    // label count mismatch
    assert!(images::save_labeled(&path, &split, &[0], 2, 2, 1, 2).is_err());
    // label out of range
    assert!(images::save_labeled(&path, &split, &[0, 5], 2, 2, 1, 2).is_err());
    assert!(!path.exists(), "a rejected save must not leave a file");
}

// ---------------------------------------------------------------------------
// committed fixtures + family validation
// ---------------------------------------------------------------------------

#[test]
fn committed_debd_fixtures_load_and_validate() {
    for (name, nv) in [("nltcs", 16usize), ("msnbc", 17)] {
        let ds = debd::load_dir(&fixtures_dir().join("debd"), name).unwrap();
        assert_eq!(ds.num_vars, nv, "{name}: fixture variable count");
        assert_eq!(ds.train.n, 400, "{name}: fixture train size");
        ds.validate_family(LeafFamily::Bernoulli)
            .expect("committed fixture must be binary");
    }
}

#[test]
fn committed_image_fixture_loads_and_validates() {
    let li = images::load_labeled(&fixtures_dir().join("images/digits3.eimg")).unwrap();
    assert_eq!((li.h, li.w, li.channels, li.classes), (4, 4, 1, 3));
    assert_eq!(li.split.n, 240);
    assert_eq!(li.labels.len(), 240);
    li.split
        .validate_family(LeafFamily::Bernoulli, "digits3")
        .expect("committed fixture must be binary");
}

#[test]
fn validate_family_rejects_arity_mismatches() {
    // categorical values under Bernoulli leaves: caught with row/variable
    let s = debd::parse_split("0,1,2\n", "cat.data").unwrap();
    assert_err_contains(
        s.validate_family(LeafFamily::Bernoulli, "cat.data"),
        "outside the support of Bernoulli",
        "categorical under Bernoulli",
    );
    assert_err_contains(
        s.validate_family(LeafFamily::Bernoulli, "cat.data"),
        "row 0, variable 2",
        "offender named",
    );
    // the same rows ARE a valid 3-ary categorical dataset
    s.validate_family(LeafFamily::Categorical { cats: 3 }, "cat.data")
        .unwrap();
    // ... but not a 2-ary one
    assert!(s
        .validate_family(LeafFamily::Categorical { cats: 2 }, "cat.data")
        .is_err());
    // row length not divisible by the observation dim (Gaussian is the
    // only multi-channel family: obs_dim == channels)
    let odd = Split {
        n: 1,
        row_len: 3,
        data: vec![0.0, 1.0, 0.0],
    };
    assert_err_contains(
        odd.validate_family(LeafFamily::Gaussian { channels: 2 }, "odd"),
        "not a multiple",
        "obs-dim mismatch",
    );
}
