//! Update-policy pinning for the online-EM subsystem.
//!
//! Two contracts:
//!
//! * **Bit-identity** — online EM at stepsize 1.0 and full-batch
//!   frequency (`UpdatePolicy { frequency: 0, schedule: Constant(1.0) }`)
//!   is the *same algorithm* as the historical per-epoch `m_step` over
//!   epoch-accumulated statistics, so the trained parameters must match
//!   bit for bit — across engines (dense / sparse / fused), structures
//!   (RAT forests and Poon–Domingos grids), the data-parallel trainer,
//!   1- and 4-shard model-parallel pools, and loopback-TCP pools. The
//!   schedule must also *override* `EmConfig::step_size` (the configs
//!   below deliberately set it to 0.5).
//! * **Monotonicity** — full-batch EM (stepsize 1.0) is the exact EM
//!   fixed-point update, so the per-epoch train log-likelihood is
//!   non-decreasing over 10 epochs on a real on-disk DEBD fixture
//!   (loaded through `data::debd::load_dir`, the file loader), for both
//!   engines and both weight structures (Dense and Monarch).

use std::path::{Path, PathBuf};
use std::sync::Arc;

use einet::coordinator::transport::spawn_loopback_workers;
use einet::coordinator::{
    train_parallel, train_sharded, ShardConfig, ShardedPool, TrainConfig,
};
use einet::data::debd;
use einet::em::{m_step, EmConfig, PolicyState, StepSchedule, UpdatePolicy};
use einet::structure::{from_spec, poon_domingos, random_binary_trees, PdAxes};
use einet::util::rng::Rng;
use einet::{
    boxed_build, DenseEngine, EinetParams, EmStats, Engine, FusedEngine,
    LayeredPlan, LeafFamily, SparseEngine, WeightStructure,
};

const EPOCHS: usize = 3;
const BATCH: usize = 16;

fn random_binary_data(n: usize, nv: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n * nv)
        .map(|_| if rng.bernoulli(0.5) { 1.0 } else { 0.0 })
        .collect()
}

/// The historical batch-EM reference: accumulate every mini-batch's
/// E-step statistics over one epoch, then apply one `m_step` at
/// stepsize 1.0 — exactly what the pre-policy full-batch trainer did.
fn batch_em_reference<E: Engine>(
    plan: &LayeredPlan,
    family: LeafFamily,
    params0: &EinetParams,
    data: &[f32],
    n: usize,
) -> EinetParams {
    let nv = plan.graph.num_vars;
    let mask = vec![1.0f32; nv];
    let em = EmConfig {
        step_size: 1.0,
        ..Default::default()
    };
    let mut params = params0.clone();
    let mut engine = E::build(plan.clone(), family, BATCH);
    let mut logp = vec![0.0f32; BATCH];
    for _ in 0..EPOCHS {
        let mut epoch_stats = EmStats::zeros_like(&params);
        let mut b0 = 0usize;
        while b0 < n {
            let bn = BATCH.min(n - b0);
            let chunk = &data[b0 * nv..(b0 + bn) * nv];
            let mut stats = EmStats::zeros_like(&params);
            engine.forward(&params, chunk, &mask, &mut logp[..bn]);
            engine.backward(&params, chunk, &mask, bn, &mut stats);
            epoch_stats.merge(&stats);
            b0 += bn;
        }
        m_step(&mut params, &epoch_stats, &em);
    }
    params
}

/// The policy under test: full-batch frequency, constant stepsize 1.0.
/// `em.step_size` is set to 0.5 everywhere below so a failure to apply
/// the schedule shows up as a parameter mismatch.
fn full_batch_unit_policy() -> UpdatePolicy {
    UpdatePolicy {
        frequency: 0,
        schedule: StepSchedule::Constant(1.0),
    }
}

fn policy_parity_case<E: Engine + Send + 'static>(
    plan: &LayeredPlan,
    seed: u64,
    label: &str,
) {
    let family = LeafFamily::Bernoulli;
    let nv = plan.graph.num_vars;
    let n = 64;
    let params0 = EinetParams::init(plan, family, seed);
    let data = random_binary_data(n, nv, seed + 1);
    let reference = batch_em_reference::<E>(plan, family, &params0, &data, n);

    // data-parallel trainer under the policy
    let mut p = params0.clone();
    let cfg = TrainConfig {
        epochs: EPOCHS,
        batch_size: BATCH,
        workers: 1,
        em: EmConfig {
            step_size: 0.5,
            ..Default::default()
        },
        policy: full_batch_unit_policy(),
        log_every: 0,
        ..Default::default()
    };
    train_parallel::<E>(plan, family, &mut p, &data, n, &cfg);
    assert_eq!(
        p.data, reference.data,
        "{label}: train_parallel online EM (freq 0, step 1.0) diverged \
         from the batch m_step reference"
    );

    // model-parallel pools, 1 and 4 shards
    for shards in [1usize, 4] {
        let mut p = params0.clone();
        let scfg = ShardConfig {
            n_shards: shards,
            epochs: EPOCHS,
            batch_size: BATCH,
            em: EmConfig {
                step_size: 0.5,
                ..Default::default()
            },
            policy: full_batch_unit_policy(),
            log_every: 0,
        };
        train_sharded(boxed_build::<E>, plan, family, &mut p, &data, n, &scfg)
            .unwrap();
        assert_eq!(
            p.data, reference.data,
            "{label} shards={shards}: sharded online EM diverged from the \
             batch m_step reference"
        );
    }
}

fn rat_plan() -> LayeredPlan {
    LayeredPlan::compile(random_binary_trees(12, 3, 3, 2), 3)
}

fn pd_plan() -> LayeredPlan {
    LayeredPlan::compile(poon_domingos(3, 4, 1, PdAxes::Both), 3)
}

#[test]
fn online_em_full_batch_identity_dense() {
    policy_parity_case::<DenseEngine>(&rat_plan(), 41, "dense/rat");
    policy_parity_case::<DenseEngine>(&pd_plan(), 42, "dense/pd");
}

#[test]
fn online_em_full_batch_identity_sparse() {
    policy_parity_case::<SparseEngine>(&rat_plan(), 43, "sparse/rat");
    policy_parity_case::<SparseEngine>(&pd_plan(), 44, "sparse/pd");
}

#[test]
fn online_em_full_batch_identity_fused() {
    policy_parity_case::<FusedEngine>(&rat_plan(), 45, "fused/rat");
    policy_parity_case::<FusedEngine>(&pd_plan(), 46, "fused/pd");
}

/// The same identity over real sockets: a 4-shard loopback-TCP pool
/// driven through `train_step_policy` lands on the batch-EM reference
/// parameters bit for bit, for every registered engine.
#[test]
fn online_em_full_batch_identity_over_loopback_tcp() {
    const NV: usize = 12;
    const STRUCTURE: &str = "rat:depth=2,replica=2,seed=3";
    let family = LeafFamily::Bernoulli;
    let n = 64;
    for engine_name in ["dense", "sparse", "fused"] {
        let plan =
            LayeredPlan::compile(from_spec(NV, STRUCTURE).unwrap(), 2);
        let params0 = EinetParams::init(&plan, family, 51);
        let data = random_binary_data(n, NV, 52);
        let reference = match engine_name {
            "dense" => {
                batch_em_reference::<DenseEngine>(&plan, family, &params0, &data, n)
            }
            "sparse" => {
                batch_em_reference::<SparseEngine>(&plan, family, &params0, &data, n)
            }
            _ => batch_em_reference::<FusedEngine>(&plan, family, &params0, &data, n),
        };

        let (addrs, handles) = spawn_loopback_workers(4).unwrap();
        let mut pool = ShardedPool::connect(
            &addrs, STRUCTURE, engine_name, &plan, family, &params0, 4, BATCH,
        )
        .expect("connect loopback pool");
        let em = EmConfig {
            step_size: 0.5,
            ..Default::default()
        };
        let policy = full_batch_unit_policy();
        let mut state = PolicyState::new(pool.params());
        let x = Arc::new(data.clone());
        let mask = Arc::new(vec![1.0f32; NV]);
        for _ in 0..EPOCHS {
            let mut b0 = 0usize;
            while b0 < n {
                let bn = BATCH.min(n - b0);
                pool.train_step_policy(
                    x.clone(),
                    b0,
                    mask.clone(),
                    bn,
                    &em,
                    &policy,
                    &mut state,
                    b0 + bn >= n,
                )
                .unwrap();
                b0 += bn;
            }
        }
        assert_eq!(
            pool.params().data, reference.data,
            "{engine_name}: loopback-TCP online EM diverged from the batch \
             m_step reference"
        );
        pool.stop();
        for h in handles {
            h.join().unwrap();
        }
    }
}

// ---------------------------------------------------------------------------
// full-batch EM monotonicity on a real on-disk DEBD fixture
// ---------------------------------------------------------------------------

fn fixtures_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("fixtures")
}

/// Train full-batch EM (exact fixed-point update: stepsize 1.0) on the
/// committed `nltcs` DEBD fixture and assert the per-epoch train LL is
/// non-decreasing (up to f32 accumulation noise) and clearly improves.
fn monotone_case<E: Engine>(monarch: bool, label: &str) {
    let family = LeafFamily::Bernoulli;
    let ds = debd::load_dir(&fixtures_dir().join("debd"), "nltcs")
        .expect("committed DEBD fixture");
    ds.validate_family(family).expect("fixture arity");
    let base = LayeredPlan::compile(random_binary_trees(ds.num_vars, 2, 2, 9), 4);
    let plan = if monarch {
        base.with_weight_structure(WeightStructure::Monarch { blocks: 2 })
            .expect("monarch blocks")
    } else {
        base
    };
    let mut params = EinetParams::init(&plan, family, 13);
    let cfg = TrainConfig {
        epochs: 10,
        batch_size: 100,
        workers: 2,
        em: EmConfig {
            step_size: 1.0,
            ..Default::default()
        },
        policy: UpdatePolicy::full_batch(),
        log_every: 0,
        ..Default::default()
    };
    let hist = train_parallel::<E>(
        &plan,
        family,
        &mut params,
        &ds.train.data,
        ds.train.n,
        &cfg,
    );
    assert_eq!(hist.len(), 10);
    for w in hist.windows(2) {
        assert!(
            w[1].train_ll >= w[0].train_ll - 5e-3,
            "{label}: full-batch EM decreased the train LL: epoch {} {} -> \
             epoch {} {}",
            w[0].epoch,
            w[0].train_ll,
            w[1].epoch,
            w[1].train_ll
        );
    }
    assert!(
        hist[9].train_ll > hist[0].train_ll + 0.2,
        "{label}: EM barely moved on the correlated fixture: {} -> {}",
        hist[0].train_ll,
        hist[9].train_ll
    );
    params.validate().unwrap();
}

#[test]
fn full_batch_em_monotone_on_debd_fixture_dense() {
    monotone_case::<DenseEngine>(false, "dense/Dense");
}

#[test]
fn full_batch_em_monotone_on_debd_fixture_sparse() {
    monotone_case::<SparseEngine>(false, "sparse/Dense");
}

#[test]
fn full_batch_em_monotone_on_debd_fixture_dense_monarch() {
    monotone_case::<DenseEngine>(true, "dense/Monarch");
}

#[test]
fn full_batch_em_monotone_on_debd_fixture_sparse_monarch() {
    monotone_case::<SparseEngine>(true, "sparse/Monarch");
}

/// The CLI policy grammar: round-trips of the `FREQ:STEP` forms and the
/// typed rejections (non-numeric, out-of-range stepsizes).
#[test]
fn update_policy_parse_grammar() {
    assert_eq!(
        UpdatePolicy::parse("1:0.05").unwrap(),
        UpdatePolicy {
            frequency: 1,
            schedule: StepSchedule::Constant(0.05),
        }
    );
    assert_eq!(
        UpdatePolicy::parse("0:1.0").unwrap(),
        UpdatePolicy {
            frequency: 0,
            schedule: StepSchedule::Constant(1.0),
        }
    );
    assert_eq!(
        UpdatePolicy::parse("8:0.5/t^0.7").unwrap(),
        UpdatePolicy {
            frequency: 8,
            schedule: StepSchedule::Decay { s0: 0.5, alpha: 0.7 },
        }
    );
    for bad in ["", "1", "x:0.5", "1:x", "1:0", "1:1.5", "1:0/t^0.7"] {
        assert!(
            UpdatePolicy::parse(bad).is_err(),
            "policy spec {bad:?} should be rejected"
        );
    }
}
