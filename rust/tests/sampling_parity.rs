//! Parity between the batched `SamplePlan` executor (`decode_batch`) and
//! the legacy per-sample graph walk (`decode`).
//!
//! Contract: in `Argmax` mode the two paths are **bit-identical** — same
//! activations, same arithmetic, same tie-breaking — across dense/sparse
//! engines, RAT and Poon–Domingos structures, every `LeafFamily`, and
//! random marginalization masks. In `Sample` mode the batched executor
//! draws every (sample, region) visit from its own counter-based stream
//! (`Rng::from_stream` under a per-call salt), which makes it
//! reproducible under ANY execution order: what we pin here is that the
//! same starting rng state yields the same batch, that a sample's draws
//! do not depend on which other rows share its batch (prefix
//! invariance), and the evidence contract. The old step-major vs
//! sample-major stream divergence is gone by construction; cross-shard
//! equality of the same streams is pinned in `tests/sharding_parity.rs`.

use einet::structure::{poon_domingos, random_binary_trees, PdAxes};
use einet::util::rng::Rng;
use einet::{
    DecodeMode, DenseEngine, EinetParams, Engine, LayeredPlan, LeafFamily,
    SparseEngine,
};

/// Draw a batch of valid observations for the family.
fn random_batch(family: LeafFamily, bn: usize, nv: usize, rng: &mut Rng) -> Vec<f32> {
    let od = family.obs_dim();
    let mut x = vec![0.0f32; bn * nv * od];
    for v in x.chunks_mut(od) {
        match family {
            LeafFamily::Bernoulli => {
                v[0] = if rng.bernoulli(0.5) { 1.0 } else { 0.0 };
            }
            LeafFamily::Gaussian { .. } => {
                for c in v.iter_mut() {
                    *c = 0.5 + 0.2 * rng.normal() as f32;
                }
            }
            LeafFamily::Categorical { cats } => {
                v[0] = rng.below(cats) as f32;
            }
            LeafFamily::Binomial { trials } => {
                v[0] = rng.below(trials as usize + 1) as f32;
            }
        }
    }
    x
}

/// A random marginalization mask that keeps at least one variable
/// observed and at least one unobserved.
fn random_mask(nv: usize, rng: &mut Rng) -> Vec<f32> {
    loop {
        let mask: Vec<f32> = (0..nv)
            .map(|_| if rng.bernoulli(0.5) { 1.0 } else { 0.0 })
            .collect();
        let ones = mask.iter().filter(|&&m| m != 0.0).count();
        if ones > 0 && ones < nv {
            return mask;
        }
    }
}

/// Argmax decode through both paths over the same forward activations
/// must agree bitwise.
fn argmax_parity_case<E: Engine>(
    plan: &LayeredPlan,
    family: LeafFamily,
    seed: u64,
    label: &str,
) {
    let nv = plan.graph.num_vars;
    let od = family.obs_dim();
    let row = nv * od;
    let bn = 6;
    let mut rng = Rng::new(seed);
    let params = EinetParams::init(plan, family, seed);
    let mut engine = E::build(plan.clone(), family, bn);
    let x = random_batch(family, bn, nv, &mut rng);
    let full = vec![1.0f32; nv];
    for (mi, mask) in [full, random_mask(nv, &mut rng), random_mask(nv, &mut rng)]
        .into_iter()
        .enumerate()
    {
        let ctx = format!("{label} family={family:?} mask#{mi}");
        let mut logp = vec![0.0f32; bn];
        engine.forward(&params, &x, &mask, &mut logp);
        let mut legacy = x.clone();
        for b in 0..bn {
            engine.decode(
                &params,
                b,
                &mask,
                DecodeMode::Argmax,
                &mut rng,
                &mut legacy[b * row..(b + 1) * row],
            );
        }
        let mut batched = x.clone();
        engine.decode_batch(
            &params,
            bn,
            &mask,
            DecodeMode::Argmax,
            &mut rng,
            &mut batched,
        );
        for (i, (a, b)) in legacy.iter().zip(&batched).enumerate() {
            assert!(
                a.to_bits() == b.to_bits(),
                "{ctx}: element {i} diverged: legacy {a} vs batched {b}"
            );
        }
    }
}

fn all_families() -> Vec<LeafFamily> {
    vec![
        LeafFamily::Bernoulli,
        LeafFamily::Gaussian { channels: 1 },
        LeafFamily::Gaussian { channels: 3 },
        LeafFamily::Categorical { cats: 4 },
        LeafFamily::Binomial { trials: 6 },
    ]
}

#[test]
fn argmax_parity_all_families_rat_dense() {
    for (i, family) in all_families().into_iter().enumerate() {
        let plan = LayeredPlan::compile(random_binary_trees(10, 3, 3, i as u64), 4);
        argmax_parity_case::<DenseEngine>(&plan, family, 40 + i as u64, "dense/rat");
    }
}

#[test]
fn argmax_parity_all_families_rat_sparse() {
    for (i, family) in all_families().into_iter().enumerate() {
        let plan = LayeredPlan::compile(random_binary_trees(10, 3, 3, i as u64), 4);
        argmax_parity_case::<SparseEngine>(&plan, family, 40 + i as u64, "sparse/rat");
    }
}

#[test]
fn argmax_parity_all_families_pd_dense() {
    // Poon–Domingos with both axes ⇒ mixing layers ⇒ the posterior-
    // weighted partition choice must also match bitwise
    for (i, family) in all_families().into_iter().enumerate() {
        let plan = LayeredPlan::compile(poon_domingos(3, 4, 1, PdAxes::Both), 3);
        argmax_parity_case::<DenseEngine>(&plan, family, 50 + i as u64, "dense/pd");
    }
}

#[test]
fn argmax_parity_all_families_pd_sparse() {
    for (i, family) in all_families().into_iter().enumerate() {
        let plan = LayeredPlan::compile(poon_domingos(3, 4, 1, PdAxes::Both), 3);
        argmax_parity_case::<SparseEngine>(&plan, family, 50 + i as u64, "sparse/pd");
    }
}

#[test]
fn unconditional_argmax_sample_matches_legacy_bitwise() {
    // the shared-row (1-row forward) fast path of sample_batch must
    // reproduce the legacy Engine::sample greedy output exactly
    let plan = LayeredPlan::compile(random_binary_trees(9, 3, 2, 7), 3);
    let family = LeafFamily::Bernoulli;
    let params = EinetParams::init(&plan, family, 7);
    let n = 5;
    let mut dense = DenseEngine::new(plan.clone(), family, n);
    let mut rng = Rng::new(0);
    let legacy = Engine::sample(&mut dense, &params, n, &mut rng, DecodeMode::Argmax);
    let batched = dense.sample_batch(&params, n, &mut rng, DecodeMode::Argmax);
    assert_eq!(legacy, batched);
}

#[test]
fn sample_mode_counter_streams_are_deterministic_and_order_independent() {
    // Sample mode under counter-based per-(sample, region) streams:
    // (a) same starting rng state ⇒ identical batch;
    // (b) prefix invariance — decoding only the first rows of the same
    //     forward pass (same starting rng state, so same salt) must
    //     reproduce those rows exactly, because no draw depends on which
    //     other rows share the batch or on the order rows are visited.
    let plan = LayeredPlan::compile(random_binary_trees(8, 2, 2, 3), 3);
    let family = LeafFamily::Bernoulli;
    let params = EinetParams::init(&plan, family, 3);
    let bn = 16;
    let mut engine = DenseEngine::new(plan, family, bn);
    let x = vec![0.0f32; bn * 8];
    let mask = vec![0.0f32; 8];
    let mut logp = vec![0.0f32; bn];
    engine.forward(&params, &x, &mask, &mut logp);

    let mut out_a = x.clone();
    let mut rng_a = Rng::new(123);
    engine.decode_batch(&params, bn, &mask, DecodeMode::Sample, &mut rng_a, &mut out_a);
    let mut out_b = x.clone();
    let mut rng_b = Rng::new(123);
    engine.decode_batch(&params, bn, &mask, DecodeMode::Sample, &mut rng_b, &mut out_b);
    assert_eq!(out_a, out_b, "same seed must reproduce the same batch");

    // prefix invariance: rows 0..8 decoded alone == rows 0..8 of the
    // full-batch decode (this is exactly what makes sharded / reordered
    // execution safe)
    let half = bn / 2;
    let mut out_half = x[..half * 8].to_vec();
    let mut rng_c = Rng::new(123);
    engine.decode_batch(
        &params,
        half,
        &mask,
        DecodeMode::Sample,
        &mut rng_c,
        &mut out_half,
    );
    assert_eq!(
        &out_a[..half * 8],
        &out_half[..],
        "a row's draws must not depend on the rest of the batch"
    );

    // different seeds produce different batches (streams really differ)
    let mut out_d = x.clone();
    let mut rng_d = Rng::new(124);
    engine.decode_batch(&params, bn, &mask, DecodeMode::Sample, &mut rng_d, &mut out_d);
    assert_ne!(out_a, out_d, "distinct seeds collapsed to one stream");

    for &v in out_a.iter().chain(&out_half) {
        assert!(v == 0.0 || v == 1.0);
    }
}

#[test]
fn conditional_decode_batch_respects_random_evidence_masks() {
    let mut seed_rng = Rng::new(77);
    for trial in 0..4 {
        let plan = LayeredPlan::compile(random_binary_trees(10, 2, 2, trial), 3);
        let family = LeafFamily::Bernoulli;
        let params = EinetParams::init(&plan, family, trial);
        let bn = 12;
        let mut engine = DenseEngine::new(plan, family, bn);
        let x = random_batch(family, bn, 10, &mut seed_rng);
        let mask = random_mask(10, &mut seed_rng);
        let mut logp = vec![0.0f32; bn];
        engine.forward(&params, &x, &mask, &mut logp);
        let mut out = x.clone();
        let mut rng = Rng::new(trial + 500);
        engine.decode_batch(&params, bn, &mask, DecodeMode::Sample, &mut rng, &mut out);
        for b in 0..bn {
            for d in 0..10 {
                if mask[d] != 0.0 {
                    assert_eq!(
                        out[b * 10 + d],
                        x[b * 10 + d],
                        "trial {trial}: observed dim {d} of sample {b} changed"
                    );
                }
            }
        }
    }
}
