//! Fault injection and cross-carrier parity for the TCP shard transport:
//! real `einet shard-worker` subprocesses behind [`ShardedPool::connect`].
//!
//! What must hold (and is asserted here):
//! * forward / EM / decode over loopback TCP are **bit-identical** to
//!   in-process sharding, including when the remote pool is built from a
//!   reloaded EINET002 checkpoint;
//! * killing a worker mid-train or mid-serve surfaces a typed
//!   [`ShardError`] (never a panic), degrades the pool to fail-fast
//!   [`ShardError::Unhealthy`], and teardown still joins cleanly;
//! * a dead worker behind an [`InferenceServer`] turns into typed
//!   [`QueryError::BackendLost`] replies while the dispatcher survives;
//! * torn / corrupt / oversized frames cost the worker one session, not
//!   the process — the next session handshakes normally.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::process::{Child, Command, Stdio};
use std::sync::Arc;
use std::time::Duration;

use einet::coordinator::server::InferenceServer;
use einet::coordinator::transport::{ShardJob, TcpTransport};
use einet::coordinator::ShardedPool;
use einet::em::EmConfig;
use einet::util::rng::Rng;
use einet::{
    boxed_build, ArenaShard, DecodeMode, DenseEngine, EinetParams, LayeredPlan,
    LeafFamily, Query, QueryAnswer, QueryError, Semiring, ServerConfig, ShardError,
    ShardTransport, WorkerConfig,
};

/// One `einet shard-worker` subprocess, killed on drop.
struct Worker {
    child: Child,
    addr: String,
}

impl Worker {
    fn spawn() -> Self {
        let mut child = Command::new(env!("CARGO_BIN_EXE_einet"))
            .args(["shard-worker", "--listen", "127.0.0.1:0"])
            .stdout(Stdio::piped())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawn einet shard-worker");
        let stdout = child.stdout.take().expect("piped stdout");
        let line = BufReader::new(stdout)
            .lines()
            .next()
            .expect("shard-worker exited before announcing its address")
            .expect("read shard-worker stdout");
        let addr = line
            .strip_prefix("listening on ")
            .unwrap_or_else(|| panic!("unexpected announcement: {line}"))
            .to_string();
        Self { child, addr }
    }

    /// Kill the process and wait until it is gone (its sockets closed).
    fn kill(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

impl Drop for Worker {
    fn drop(&mut self) {
        self.kill();
    }
}

fn spawn_workers(n: usize) -> (Vec<Worker>, Vec<String>) {
    let workers: Vec<Worker> = (0..n).map(|_| Worker::spawn()).collect();
    let addrs = workers.iter().map(|w| w.addr.clone()).collect();
    (workers, addrs)
}

const NV: usize = 16;
const STRUCTURE: &str = "rat:depth=2,replica=3,seed=5";
const K: usize = 3;

fn build_plan() -> LayeredPlan {
    let graph = einet::structure::from_spec(NV, STRUCTURE).expect("structure spec");
    LayeredPlan::compile(graph, K)
}

fn binary_batch(bn: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..bn * NV)
        .map(|_| if rng.bernoulli(0.5) { 1.0 } else { 0.0 })
        .collect()
}

#[test]
fn tcp_pool_matches_in_process_bitwise_from_checkpoint() {
    let plan = build_plan();
    let family = LeafFamily::Bernoulli;
    let params = EinetParams::init(&plan, family, 9);

    // round-trip the parameters through an EINET002 checkpoint: the
    // remote pool restarts from disk exactly as a redeployed server would
    let ckpt = std::env::temp_dir().join(format!(
        "einet_transport_faults_{}.einet",
        std::process::id()
    ));
    params.save(&ckpt).expect("save checkpoint");
    let reloaded = EinetParams::load(&ckpt).expect("load checkpoint");
    let _ = std::fs::remove_file(&ckpt);
    assert_eq!(params.data, reloaded.data, "checkpoint round-trip drifted");

    let bn = 8usize;
    let x = binary_batch(bn, 2);
    let mut mask = vec![1.0f32; NV];
    for m in mask.iter_mut().skip(NV / 2) {
        *m = 0.0;
    }
    let full = vec![1.0f32; NV];
    let em = EmConfig {
        step_size: 0.5,
        ..Default::default()
    };

    // --- in-process reference -----------------------------------------
    let mut pool = ShardedPool::new(boxed_build::<DenseEngine>, &plan, family, &params, 3, bn);
    let mut lp_ref = vec![0.0f32; bn];
    pool.forward(&x, &mask, bn, &mut lp_ref).unwrap();
    let mut out_ref = x.clone();
    let mut rng = Rng::new(77);
    pool.decode(bn, &mask, DecodeMode::Sample, &mut rng, &mut out_ref)
        .unwrap();
    let ll_ref = pool.train_step(&x, &full, bn, &em).unwrap();
    let params_ref = pool.params().data.clone();
    pool.stop();

    // --- loopback-TCP pool over real shard-worker processes ------------
    let (_workers, addrs) = spawn_workers(3);
    let mut tcp = ShardedPool::connect(
        &addrs, STRUCTURE, "dense", &plan, family, &reloaded, 3, bn,
    )
    .expect("connect TCP pool");
    let mut lp = vec![0.0f32; bn];
    tcp.forward(&x, &mask, bn, &mut lp).unwrap();
    for (a, b) in lp_ref.iter().zip(&lp) {
        assert_eq!(a.to_bits(), b.to_bits(), "TCP forward diverged");
    }
    let mut out = x.clone();
    let mut rng = Rng::new(77);
    tcp.decode(bn, &mask, DecodeMode::Sample, &mut rng, &mut out)
        .unwrap();
    assert_eq!(out_ref, out, "TCP Sample decode diverged");
    let ll = tcp.train_step(&x, &full, bn, &em).unwrap();
    assert_eq!(
        ll_ref.to_bits(),
        ll.to_bits(),
        "TCP EM log-likelihood diverged"
    );
    assert_eq!(
        params_ref,
        tcp.params().data,
        "TCP EM parameter update diverged"
    );
    tcp.stop();
}

#[test]
fn killing_a_worker_mid_serve_yields_typed_errors() {
    let plan = build_plan();
    let family = LeafFamily::Bernoulli;
    let params = EinetParams::init(&plan, family, 4);
    let bn = 4usize;
    let (mut workers, addrs) = spawn_workers(2);
    let mut pool = ShardedPool::connect(
        &addrs, STRUCTURE, "dense", &plan, family, &params, 2, bn,
    )
    .expect("connect TCP pool");

    let x = binary_batch(bn, 3);
    let mask = vec![1.0f32; NV];
    let mut lp = vec![0.0f32; bn];
    pool.forward(&x, &mask, bn, &mut lp).unwrap();
    assert!(pool.healthy());

    // shard 0 is always connected, even if the cut folded empty segments
    workers[0].kill();
    let err = pool
        .forward(&x, &mask, bn, &mut lp)
        .expect_err("forward over a dead worker must fail");
    assert!(
        matches!(err, ShardError::WorkerLost(_) | ShardError::Frame { .. }),
        "wrong failure kind: {err}"
    );
    assert!(!pool.healthy());
    assert!(pool.failure().is_some());

    // degraded pool fails fast from here on — no hang, no panic
    let err = pool
        .forward(&x, &mask, bn, &mut lp)
        .expect_err("degraded pool must fail fast");
    assert_eq!(err, ShardError::Unhealthy);
    pool.stop(); // joins the surviving worker's link cleanly
}

#[test]
fn killing_a_worker_mid_train_degrades_without_panicking() {
    let plan = build_plan();
    let family = LeafFamily::Bernoulli;
    let params = EinetParams::init(&plan, family, 6);
    let bn = 4usize;
    let (mut workers, addrs) = spawn_workers(2);
    let mut pool = ShardedPool::connect(
        &addrs, STRUCTURE, "dense", &plan, family, &params, 2, bn,
    )
    .expect("connect TCP pool");

    let x = binary_batch(bn, 5);
    let mask = vec![1.0f32; NV];
    let em = EmConfig {
        step_size: 0.5,
        ..Default::default()
    };
    pool.train_step(&x, &mask, bn, &em)
        .expect("healthy pool trains");

    workers[0].kill();
    let err = pool
        .train_step(&x, &mask, bn, &em)
        .expect_err("training over a dead worker must fail");
    assert!(
        matches!(err, ShardError::WorkerLost(_) | ShardError::Frame { .. }),
        "wrong failure kind: {err}"
    );
    let err = pool
        .train_step(&x, &mask, bn, &em)
        .expect_err("degraded pool must fail fast");
    assert_eq!(err, ShardError::Unhealthy);
    pool.stop();
}

#[test]
fn server_answers_backend_lost_after_worker_death() {
    let plan = build_plan();
    let family = LeafFamily::Bernoulli;
    let params = EinetParams::init(&plan, family, 8);
    let (mut workers, addrs) = spawn_workers(2);
    let server = InferenceServer::start_remote(
        &addrs,
        STRUCTURE,
        "dense",
        plan,
        family,
        params,
        2,
        ServerConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(1),
            ..ServerConfig::default()
        },
    )
    .expect("start remote server");

    let x = binary_batch(1, 7);
    let ans = server.run_query(x.clone(), Query::LogLik);
    assert!(ans.score.is_finite());

    workers[0].kill();
    // the group being served when the pool degrades — and everything
    // after it — gets a typed BackendLost reply; the dispatcher survives
    for _ in 0..2 {
        let reply = server
            .submit_query(x.clone(), Query::LogLik)
            .recv()
            .expect("dispatcher must answer, not die");
        assert!(
            matches!(reply, QueryAnswer::Err(QueryError::BackendLost)),
            "expected BackendLost, got {reply:?}"
        );
    }
    let stats = server.stop();
    assert_eq!(stats.queries, 1);
    assert_eq!(stats.rej_backend_lost, 2);
}

#[test]
fn corrupt_frames_cost_one_session_not_the_worker() {
    let plan = build_plan();
    let family = LeafFamily::Bernoulli;
    let params = EinetParams::init(&plan, family, 1);
    let bn = 2usize;
    let (_workers, addrs) = spawn_workers(1);

    // session 1: an oversized length prefix (4 GiB frame) — rejected
    // before any allocation, session dropped
    {
        let mut s = TcpStream::connect(&addrs[0]).unwrap();
        s.write_all(&u32::MAX.to_le_bytes()).unwrap();
        s.write_all(&[4u8]).unwrap();
    }
    // session 2: a torn frame — the length promises more bytes than
    // arrive before EOF
    {
        let mut s = TcpStream::connect(&addrs[0]).unwrap();
        s.write_all(&100u32.to_le_bytes()).unwrap();
        s.write_all(&[1u8, 2, 3]).unwrap();
    }
    // session 3: junk that parses as no config frame at all
    {
        let mut s = TcpStream::connect(&addrs[0]).unwrap();
        s.write_all(b"GET / HTTP/1.1\r\n\r\n").unwrap();
    }
    // session 4: a well-formed handshake the worker must REFUSE (unknown
    // engine) — the refusal travels back as a typed Handshake error
    let cfg = WorkerConfig {
        structure: STRUCTURE.to_string(),
        weights: "dense".to_string(),
        num_vars: NV,
        k: K,
        family,
        engine: "no-such-engine".to_string(),
        n_shards: 1,
        shard_id: 0,
        batch_cap: bn,
        fastmath: false,
        classes: 1,
    };
    let err = TcpTransport::connect(&addrs[0], &cfg, NV)
        .expect_err("unknown engine must be refused");
    assert!(
        matches!(err, ShardError::Handshake { .. }),
        "wrong failure kind: {err}"
    );

    // session 5: after all of the abuse, a real pool still connects and
    // serves — corruption cost sessions, never the process
    let mut pool = ShardedPool::connect(
        &addrs, STRUCTURE, "dense", &plan, family, &params, 1, bn,
    )
    .expect("worker must survive corrupt sessions");
    let x = binary_batch(bn, 11);
    let mask = vec![1.0f32; NV];
    let mut lp = vec![0.0f32; bn];
    pool.forward(&x, &mask, bn, &mut lp).unwrap();
    assert!(lp.iter().all(|l| l.is_finite()));
    pool.stop();
}

#[test]
fn crafted_payloads_cost_one_session_not_the_worker() {
    // frames that parse fine but carry semantically malformed contents:
    // without worker-side validation each of these would panic a slice
    // index inside the engine and kill the whole process
    let plan = build_plan();
    let family = LeafFamily::Bernoulli;
    let params = EinetParams::init(&plan, family, 3);
    let bn = 2usize;
    let (_workers, addrs) = spawn_workers(1);
    let cfg = WorkerConfig {
        structure: STRUCTURE.to_string(),
        weights: "dense".to_string(),
        num_vars: NV,
        k: K,
        family,
        engine: "dense".to_string(),
        n_shards: 1,
        shard_id: 0,
        batch_cap: bn,
        fastmath: false,
        classes: 1,
    };
    let row = NV; // Bernoulli evidence: one scalar per variable
    let sessions: Vec<(&str, ShardJob)> = vec![
        (
            "mask shorter than the variable count",
            ShardJob::Forward {
                x: Arc::new(vec![0.0; bn * row]),
                row0: 0,
                mask: Arc::new(vec![1.0; 3]),
                bn,
                sr: Semiring::SumProduct,
            },
        ),
        (
            "boundary gradient vector far too short",
            ShardJob::Backward {
                x: Arc::new(vec![0.0; bn * row]),
                row0: 0,
                mask: Arc::new(vec![1.0; NV]),
                bn,
                grads: vec![0.0; 2],
            },
        ),
        (
            "parameter span past the arena end",
            ShardJob::Params(ArenaShard {
                spans: vec![(1 << 28, (1 << 28) + 8)],
                data: vec![0.0; 8],
            }),
        ),
        (
            "sel table with the wrong entry count",
            ShardJob::Decode {
                mask: Arc::new(vec![0.0; NV]),
                mode: DecodeMode::Argmax,
                bn,
                salt: 9,
                sel: vec![0; 1],
            },
        ),
    ];
    for (what, job) in sessions {
        let mut t = TcpTransport::connect(&addrs[0], &cfg, row)
            .unwrap_or_else(|e| panic!("handshake before `{what}` failed: {e}"));
        t.send(job).unwrap_or_else(|e| panic!("send `{what}` failed: {e}"));
        let err = t
            .recv()
            .expect_err("worker must drop the session, not answer");
        assert!(
            matches!(err, ShardError::WorkerLost(_) | ShardError::Frame { .. }),
            "`{what}`: wrong failure kind: {err}"
        );
    }

    // the worker process survived every crafted session: a real pool
    // still connects and serves bit-normal answers
    let mut pool = ShardedPool::connect(
        &addrs, STRUCTURE, "dense", &plan, family, &params, 1, bn,
    )
    .expect("worker must survive crafted sessions");
    let x = binary_batch(bn, 13);
    let mask = vec![1.0f32; NV];
    let mut lp = vec![0.0f32; bn];
    pool.forward(&x, &mask, bn, &mut lp).unwrap();
    assert!(lp.iter().all(|l| l.is_finite()));
    pool.stop();
}
