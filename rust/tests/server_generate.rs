//! Round-trip test for the generation endpoint: train a model, write an
//! EINET002 checkpoint, reload it, serve it, and verify that batched
//! conditional samples respect the evidence mask exactly (observed dims
//! bit-untouched) while completions stay in the observation domain.

use std::time::Duration;

use einet::coordinator::server::InferenceServer;
use einet::em::{m_step, EmConfig};
use einet::structure::random_binary_trees;
use einet::util::rng::Rng;
use einet::{
    DecodeMode, DenseEngine, EinetParams, EmStats, LayeredPlan, LeafFamily,
    SparseEngine,
};

/// Two-mode binary data: rows are mostly-ones or mostly-zeros.
fn two_mode_data(n: usize, nv: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    let mut x = vec![0.0f32; n * nv];
    for b in 0..n {
        let p = if rng.bernoulli(0.5) { 0.9 } else { 0.1 };
        for d in 0..nv {
            x[b * nv + d] = if rng.bernoulli(p) { 1.0 } else { 0.0 };
        }
    }
    x
}

/// A few stochastic-EM sweeps, enough to move the model off init.
fn quick_train(
    plan: &LayeredPlan,
    family: LeafFamily,
    data: &[f32],
    n: usize,
    nv: usize,
) -> EinetParams {
    let mut params = EinetParams::init(plan, family, 0);
    let mut engine = DenseEngine::new(plan.clone(), family, 64);
    let mask = vec![1.0f32; nv];
    let cfg = EmConfig {
        step_size: 0.5,
        ..Default::default()
    };
    let mut stats = EmStats::zeros_like(&params);
    let mut logp = vec![0.0f32; 64];
    for _epoch in 0..3 {
        let mut b0 = 0usize;
        while b0 < n {
            let bn = 64.min(n - b0);
            stats.reset();
            engine.forward(
                &params,
                &data[b0 * nv..(b0 + bn) * nv],
                &mask,
                &mut logp[..bn],
            );
            engine.backward(&params, &data[b0 * nv..(b0 + bn) * nv], &mask, bn, &mut stats);
            m_step(&mut params, &stats, &cfg);
            b0 += bn;
        }
    }
    params
}

#[test]
fn generation_endpoint_checkpoint_round_trip() {
    let nv = 8;
    let family = LeafFamily::Bernoulli;
    let plan = LayeredPlan::compile(random_binary_trees(nv, 2, 2, 1), 3);
    let n = 256;
    let data = two_mode_data(n, nv, 2);
    let params = quick_train(&plan, family, &data, n, nv);

    // checkpoint round trip through the ZERO-COPY serving path: EINET002
    // save + mmap load (same bounds checks as the buffered load; on
    // non-unix or without the `mmap` feature this transparently falls
    // back to the buffered read)
    let path = std::env::temp_dir().join("einet_test_server_gen_ckpt.bin");
    params.save(&path).unwrap();
    let loaded = EinetParams::load_mapped(&path).unwrap();
    assert_eq!(params.layout, loaded.layout);
    assert_eq!(params.data, loaded.data);
    loaded.validate().unwrap();
    #[cfg(all(unix, feature = "mmap"))]
    assert!(
        loaded.data.is_mapped(),
        "serving load should be backed by the mapping, not a heap copy"
    );
    let _ = std::fs::remove_file(&path);

    // serve the reloaded model
    let server = InferenceServer::start_seeded::<DenseEngine>(
        plan.clone(),
        family,
        loaded,
        16,
        Duration::from_millis(5),
        42,
    );
    // evidence: first half observed (all ones), second half generated
    let mut mask = vec![0.0f32; nv];
    for d in 0..nv / 2 {
        mask[d] = 1.0;
    }
    let receivers: Vec<_> = (0..24)
        .map(|i| {
            let mut x = vec![0.0f32; nv];
            for d in 0..nv / 2 {
                x[d] = ((i + d) % 2) as f32;
            }
            (
                x.clone(),
                server.submit_generate(x, mask.clone(), DecodeMode::Sample),
            )
        })
        .collect();
    let mut completions = Vec::new();
    for (x, rx) in receivers {
        let out = rx.recv().unwrap();
        assert_eq!(out.len(), nv);
        for d in 0..nv {
            if mask[d] != 0.0 {
                assert!(
                    out[d].to_bits() == x[d].to_bits(),
                    "observed dim {d} changed: {} -> {}",
                    x[d],
                    out[d]
                );
            } else {
                assert!(out[d] == 0.0 || out[d] == 1.0, "non-binary completion");
            }
        }
        completions.push(out);
    }
    // marginal queries still served on the same dispatcher
    let lp = server.query(vec![1.0f32; nv], vec![1.0f32; nv]);
    assert!(lp.is_finite() && lp < 0.0, "marginal query broken: {lp}");
    let stats = server.stop();
    assert_eq!(stats.generated, 24);
    assert_eq!(stats.queries, 1);
}

#[test]
fn generation_endpoint_argmax_is_reproducible_across_backends() {
    // Argmax generation is deterministic, so the dense and sparse
    // dispatchers must agree on identical requests (both engines leave
    // the same activations and run the same SamplePlan executor)
    let nv = 6;
    let family = LeafFamily::Bernoulli;
    let plan = LayeredPlan::compile(random_binary_trees(nv, 2, 2, 9), 3);
    let params = EinetParams::init(&plan, family, 9);
    let mask = vec![1.0f32, 0.0, 1.0, 0.0, 0.0, 0.0];
    let x = vec![1.0f32, 0.0, 1.0, 0.0, 0.0, 0.0];

    let dense_server = InferenceServer::start_seeded::<DenseEngine>(
        plan.clone(),
        family,
        params.clone(),
        8,
        Duration::from_millis(2),
        7,
    );
    let a = dense_server.generate(x.clone(), mask.clone(), DecodeMode::Argmax);
    let b = dense_server.generate(x.clone(), mask.clone(), DecodeMode::Argmax);
    dense_server.stop();
    assert_eq!(a, b, "Argmax generation must be deterministic");

    let sparse_server = InferenceServer::start_seeded::<SparseEngine>(
        plan,
        family,
        params,
        8,
        Duration::from_millis(2),
        7,
    );
    let c = sparse_server.generate(x.clone(), mask, DecodeMode::Argmax);
    sparse_server.stop();
    // the sparse backend serves the same contract (evidence untouched,
    // binary completions); exact cross-engine equality is not asserted —
    // the two layouts may round differently at argmax near-ties
    assert_eq!(c[0], x[0]);
    assert_eq!(c[2], x[2]);
    for &v in &c {
        assert!(v == 0.0 || v == 1.0);
    }
}

#[test]
fn mapped_load_rides_the_same_bounds_checks() {
    // truncation and corruption must error through `load_mapped` exactly
    // like the buffered `load` — the mmap path parses the same header
    // with the same validation before any view is handed out
    let nv = 6;
    let plan = LayeredPlan::compile(random_binary_trees(nv, 2, 2, 4), 3);
    let params = EinetParams::init(&plan, LeafFamily::Bernoulli, 4);
    let full_path = std::env::temp_dir().join("einet_test_mmap_full.bin");
    params.save(&full_path).unwrap();
    let full = std::fs::read(&full_path).unwrap();
    let path = std::env::temp_dir().join("einet_test_mmap_trunc.bin");
    let cuts = [3usize, 9, 40, 64, full.len() / 2, full.len() - 5, full.len() - 1];
    for &cut in cuts.iter().filter(|&&c| c < full.len()) {
        std::fs::write(&path, &full[..cut]).unwrap();
        assert!(
            EinetParams::load_mapped(&path).is_err(),
            "mapped load accepted a file truncated at {cut}"
        );
    }
    let mut bad = full.clone();
    bad[0] = b'X'; // magic
    std::fs::write(&path, &bad).unwrap();
    assert!(EinetParams::load_mapped(&path).is_err(), "bad magic accepted");
    bad[0] = b'E';
    bad[8] = 200; // unknown family tag
    std::fs::write(&path, &bad).unwrap();
    assert!(
        EinetParams::load_mapped(&path).is_err(),
        "bad family tag accepted"
    );
    // and the good file still loads and is bit-identical to the source
    let ok = EinetParams::load_mapped(&full_path).unwrap();
    assert_eq!(ok.data, params.data);
    let _ = std::fs::remove_file(full_path);
    let _ = std::fs::remove_file(path);
}
