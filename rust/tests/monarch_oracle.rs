//! Monarch weight-structure oracle and parity suite.
//!
//! The anchor is the **dense-expansion oracle**: a Monarch level stores
//! two thin block-diagonal factors whose expansion
//! `W[ko][(g,r),(s,g')] = L[ko][g][r,s] * R[ko][s][g,g']` is an ordinary
//! dense `[K, K]` einsum block. Expanding the factors of a
//! Monarch-structured plan into a dense plan over the same region graph
//! must reproduce forward log-likelihoods and max-product (MPE) scores —
//! across every engine (dense / sparse / fused), both structure families
//! (RAT forests and Poon–Domingos grids with mixing layers), and every
//! leaf family. On top of the oracle:
//!
//! * fused vs dense on Monarch plans is **bit-identical** (forward and
//!   EM statistics — the fused contract does not weaken for structured
//!   levels);
//! * EM on the factors keeps the conditional-decomposition normalization
//!   invariants and improves training log-likelihood;
//! * 1-shard vs 4-shard execution (in-process channels and loopback TCP
//!   with the v2 weight-structure handshake) is bit-identical;
//! * EINET003 checkpoints round-trip, dense checkpoints stay EINET002
//!   byte-compatible, and structure mismatches fail with the typed
//!   `weight-structure mismatch` error instead of misreading spans.

use einet::coordinator::transport::spawn_loopback_workers;
use einet::coordinator::ShardedPool;
use einet::em::{m_step, EmConfig};
use einet::structure::{from_spec, poon_domingos, random_binary_trees, PdAxes};
use einet::util::rng::Rng;
use einet::{
    boxed_build, DecodeMode, DenseEngine, EinetParams, EmStats, Engine,
    FusedEngine, LayeredPlan, LeafFamily, ParamLayout, SparseEngine,
    WeightStructure,
};

/// Draw a batch of valid observations for the family.
fn random_batch(family: LeafFamily, bn: usize, nv: usize, rng: &mut Rng) -> Vec<f32> {
    let od = family.obs_dim();
    let mut x = vec![0.0f32; bn * nv * od];
    for v in x.chunks_mut(od) {
        match family {
            LeafFamily::Bernoulli => {
                v[0] = if rng.bernoulli(0.5) { 1.0 } else { 0.0 };
            }
            LeafFamily::Gaussian { .. } => {
                for c in v.iter_mut() {
                    *c = 0.5 + 0.2 * rng.normal() as f32;
                }
            }
            LeafFamily::Categorical { cats } => {
                v[0] = rng.below(cats) as f32;
            }
            LeafFamily::Binomial { trials } => {
                v[0] = rng.below(trials as usize + 1) as f32;
            }
        }
    }
    x
}

/// A random marginalization mask that keeps at least one variable.
fn random_mask(nv: usize, rng: &mut Rng) -> Vec<f32> {
    loop {
        let mask: Vec<f32> = (0..nv)
            .map(|_| if rng.bernoulli(0.6) { 1.0 } else { 0.0 })
            .collect();
        if mask.iter().any(|&m| m != 0.0) {
            return mask;
        }
    }
}

fn monarch_plan(plan: LayeredPlan, blocks: usize) -> LayeredPlan {
    plan.with_weight_structure(WeightStructure::Monarch { blocks })
        .expect("valid monarch block count")
}

/// Expand a Monarch parameter arena into the dense arena of the same
/// region graph: theta and mixing spans copy verbatim, every factor pair
/// expands to its logical `[K, K]` block. This is the ground truth the
/// structured execution paths are checked against.
fn expand_to_dense(
    mplan: &LayeredPlan,
    params: &EinetParams,
    family: LeafFamily,
) -> (LayeredPlan, EinetParams) {
    let dplan = LayeredPlan::compile(mplan.graph.clone(), mplan.k);
    let mut dp = EinetParams::zeros(ParamLayout::from_plan(&dplan, family));
    let k = mplan.k;
    let ml = &params.layout;
    dp.data[..ml.theta_len].copy_from_slice(&params.data[..ml.theta_len]);
    let dlevels = dp.layout.levels.clone();
    for (lm, ld) in ml.levels.iter().zip(&dlevels) {
        match lm.structure {
            WeightStructure::Dense => {
                dp.data[ld.w_off..ld.w_off + ld.w_len]
                    .copy_from_slice(&params.data[lm.w_off..lm.w_off + lm.w_len]);
            }
            WeightStructure::Monarch { blocks } => {
                let q = k / blocks;
                for be in 0..lm.slots * lm.ko {
                    let l = &params.data
                        [lm.w_off + be * k * q..lm.w_off + (be + 1) * k * q];
                    let r = &params.data[lm.w2_off + be * k * blocks
                        ..lm.w2_off + (be + 1) * k * blocks];
                    let w = &mut dp.data
                        [ld.w_off + be * k * k..ld.w_off + (be + 1) * k * k];
                    for ii in 0..k {
                        let g = ii / q;
                        for jj in 0..k {
                            let s = jj / blocks;
                            let gp = jj % blocks;
                            w[ii * k + jj] =
                                l[ii * q + s] * r[(s * blocks + g) * blocks + gp];
                        }
                    }
                }
            }
        }
        if let (Some(mm), Some(md)) = (&lm.mix, &ld.mix) {
            dp.data[md.off..md.off + md.len]
                .copy_from_slice(&params.data[mm.off..mm.off + mm.len]);
        }
    }
    (dplan, dp)
}

/// Run forward + backward through any engine.
fn run_engine<E: Engine>(
    plan: &LayeredPlan,
    family: LeafFamily,
    params: &EinetParams,
    x: &[f32],
    mask: &[f32],
    bn: usize,
) -> (Vec<f32>, EmStats) {
    let mut engine = E::build(plan.clone(), family, bn);
    let mut logp = vec![0.0f32; bn];
    engine.forward(params, x, mask, &mut logp);
    let mut stats = EmStats::zeros_like(params);
    engine.backward(params, x, mask, bn, &mut stats);
    (logp, stats)
}

fn assert_stats_close(a: &EmStats, b: &EmStats, ctx: &str) {
    assert_eq!(a.count, b.count, "{ctx}: count");
    assert!(
        (a.loglik - b.loglik).abs() < 1e-3 * (1.0 + a.loglik.abs()),
        "{ctx}: loglik {} vs {}",
        a.loglik,
        b.loglik
    );
    for (i, (x, y)) in a.grad.iter().zip(&b.grad).enumerate() {
        assert!(
            (x - y).abs() < 3e-3 * (1.0 + x.abs()),
            "{ctx}: grad[{i}] {x} vs {y}"
        );
    }
}

fn close(a: f32, b: f32) -> bool {
    (a - b).abs() < 2e-3 * (1.0 + a.abs())
}

/// The full oracle for one (plan, family) pair: every engine on the
/// Monarch plan vs the dense engine on the expanded plan (forward and
/// MPE), plus cross-engine EM parity with fused bit-identity.
fn oracle_case(mplan: &LayeredPlan, family: LeafFamily, seed: u64, label: &str) {
    let nv = mplan.graph.num_vars;
    let bn = 8;
    let mut rng = Rng::new(seed);
    let params = EinetParams::init(mplan, family, seed);
    params.validate().expect("monarch init normalized");
    let (dplan, dparams) = expand_to_dense(mplan, &params, family);
    // expanding normalized factors yields a normalized dense block
    dparams.validate().expect("expanded dense params normalized");

    let x = random_batch(family, bn, nv, &mut rng);
    let full = vec![1.0f32; nv];
    for (mi, mask) in [full, random_mask(nv, &mut rng)].into_iter().enumerate() {
        let ctx = format!("{label} family={family:?} mask#{mi}");
        let (lp_ref, _) =
            run_engine::<DenseEngine>(&dplan, family, &dparams, &x, &mask, bn);
        let (lp_d, st_d) = run_engine::<DenseEngine>(mplan, family, &params, &x, &mask, bn);
        let (lp_s, st_s) = run_engine::<SparseEngine>(mplan, family, &params, &x, &mask, bn);
        let (lp_f, st_f) = run_engine::<FusedEngine>(mplan, family, &params, &x, &mask, bn);
        for b in 0..bn {
            assert!(lp_d[b].is_finite(), "{ctx}: monarch logp[{b}] not finite");
            assert!(
                close(lp_ref[b], lp_d[b]),
                "{ctx}: row {b} dense-expansion {} vs monarch dense {}",
                lp_ref[b],
                lp_d[b]
            );
            assert!(
                close(lp_ref[b], lp_s[b]),
                "{ctx}: row {b} dense-expansion {} vs monarch sparse {}",
                lp_ref[b],
                lp_s[b]
            );
            assert_eq!(
                lp_d[b].to_bits(),
                lp_f[b].to_bits(),
                "{ctx}: row {b} fused must be bit-identical to dense"
            );
        }
        // EM statistics: sparse agrees within tolerance, fused delegates
        // its backward to the dense machinery and must match bit-for-bit
        assert_stats_close(&st_d, &st_s, &ctx);
        assert_eq!(st_d.loglik.to_bits(), st_f.loglik.to_bits(), "{ctx}: fused loglik");
        for (i, (a, b)) in st_d.grad.iter().zip(&st_f.grad).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "{ctx}: fused grad[{i}] diverged");
        }
    }

    // MPE (max-product semiring): the unique-path factorization is exact
    // under max too, so scores match the expanded model
    let mut mask = vec![1.0f32; nv];
    for m in mask.iter_mut().skip(nv / 2) {
        *m = 0.0;
    }
    let mut e_ref = DenseEngine::new(dplan.clone(), family, bn);
    let mut e_d = DenseEngine::new(mplan.clone(), family, bn);
    let mut e_f = FusedEngine::new(mplan.clone(), family, bn);
    let (_, sc_ref) = einet::infer::mpe(&mut e_ref, &dparams, &x, &mask, bn);
    let (rows_d, sc_d) = einet::infer::mpe(&mut e_d, &params, &x, &mask, bn);
    let (rows_f, sc_f) = einet::infer::mpe(&mut e_f, &params, &x, &mask, bn);
    for b in 0..bn {
        assert!(
            close(sc_ref[b], sc_d[b]),
            "{label} family={family:?}: MPE score {b} expansion {} vs monarch {}",
            sc_ref[b],
            sc_d[b]
        );
        assert_eq!(
            sc_d[b].to_bits(),
            sc_f[b].to_bits(),
            "{label} family={family:?}: fused MPE score {b} diverged"
        );
    }
    assert_eq!(rows_d, rows_f, "{label} family={family:?}: fused MPE rows diverged");
}

fn all_families() -> Vec<LeafFamily> {
    vec![
        LeafFamily::Bernoulli,
        LeafFamily::Gaussian { channels: 1 },
        LeafFamily::Gaussian { channels: 3 },
        LeafFamily::Categorical { cats: 4 },
        LeafFamily::Binomial { trials: 6 },
    ]
}

#[test]
fn monarch_oracle_rat_structure() {
    for (i, family) in all_families().into_iter().enumerate() {
        for blocks in [2usize, 4] {
            let plan = monarch_plan(
                LayeredPlan::compile(random_binary_trees(10, 3, 2, i as u64), 8),
                blocks,
            );
            oracle_case(&plan, family, 10 + i as u64, &format!("rat/b{blocks}"));
        }
    }
}

#[test]
fn monarch_oracle_pd_mixing_structure() {
    // Poon–Domingos with both axes ⇒ multi-partition regions ⇒ mixing
    // layers riding above Monarch einsum levels
    for (i, family) in [LeafFamily::Bernoulli, LeafFamily::Gaussian { channels: 1 }]
        .into_iter()
        .enumerate()
    {
        for blocks in [2usize, 3] {
            let plan =
                monarch_plan(LayeredPlan::compile(poon_domingos(3, 4, 1, PdAxes::Both), 6), blocks);
            oracle_case(&plan, family, 20 + i as u64, &format!("pd/b{blocks}"));
        }
    }
}

#[test]
fn monarch_has_fewer_parameters_and_em_improves_loglik() {
    let family = LeafFamily::Bernoulli;
    let nv = 12;
    let base = LayeredPlan::compile(random_binary_trees(nv, 3, 3, 4), 8);
    let dense_params = EinetParams::init(&base, family, 4);
    let plan = monarch_plan(base, 2);
    let mut params = EinetParams::init(&plan, family, 4);
    assert!(
        params.num_params() < dense_params.num_params(),
        "monarch K=8 b=2 must be smaller than dense: {} vs {}",
        params.num_params(),
        dense_params.num_params()
    );

    let bn = 64;
    let mut rng = Rng::new(7);
    let x = random_batch(family, bn, nv, &mut rng);
    let mask = vec![1.0f32; nv];
    let em = EmConfig { step_size: 0.7, ..Default::default() };
    let mut engine = DenseEngine::new(plan.clone(), family, bn);
    let mut ll = Vec::new();
    for _ in 0..6 {
        let mut logp = vec![0.0f32; bn];
        engine.forward(&params, &x, &mask, &mut logp);
        ll.push(logp.iter().map(|&l| l as f64).sum::<f64>() / bn as f64);
        let mut stats = EmStats::zeros_like(&params);
        engine.backward(&params, &x, &mask, bn, &mut stats);
        m_step(&mut params, &stats, &em);
        // the factor-group m-step must preserve the conditional
        // decomposition invariants every step
        params.validate().expect("monarch params normalized after m_step");
    }
    assert!(
        ll.last().unwrap() > &(ll[0] + 1e-3),
        "EM on monarch factors failed to improve LL: {ll:?}"
    );
}

#[test]
fn monarch_sampling_is_deterministic_and_fused_matches_dense() {
    let family = LeafFamily::Bernoulli;
    let plan = monarch_plan(LayeredPlan::compile(random_binary_trees(10, 3, 2, 2), 8), 4);
    let params = EinetParams::init(&plan, family, 11);
    let n = 12;
    let mut e_d = DenseEngine::new(plan.clone(), family, n);
    let mut e_f = FusedEngine::new(plan.clone(), family, n);
    let s_d = e_d.sample_batch(&params, n, &mut Rng::new(5), DecodeMode::Sample);
    let s_d2 = e_d.sample_batch(&params, n, &mut Rng::new(5), DecodeMode::Sample);
    let s_f = e_f.sample_batch(&params, n, &mut Rng::new(5), DecodeMode::Sample);
    assert_eq!(s_d, s_d2, "monarch sampling must be seed-deterministic");
    assert_eq!(s_d, s_f, "fused sampling diverged from dense on a monarch plan");
    // conditional decode (posterior materialized per logical row) too
    let nv = plan.graph.num_vars;
    let mut mask = vec![1.0f32; nv];
    for m in mask.iter_mut().skip(nv / 2) {
        *m = 0.0;
    }
    let mut rng = Rng::new(23);
    let x = random_batch(family, n, nv, &mut rng);
    let mut out_d = x.clone();
    let mut out_f = x.clone();
    e_d.forward(&params, &x, &mask, &mut vec![0.0f32; n]);
    e_d.decode_batch(&params, n, &mask, DecodeMode::Argmax, &mut Rng::new(3), &mut out_d);
    e_f.forward(&params, &x, &mask, &mut vec![0.0f32; n]);
    e_f.decode_batch(&params, n, &mask, DecodeMode::Argmax, &mut Rng::new(3), &mut out_f);
    assert_eq!(out_d, out_f, "fused Argmax decode diverged on a monarch plan");
}

/// In-process 1-shard vs 4-shard bit-identity on Monarch plans: forward,
/// reduced EM statistics + stepped parameters, Argmax and Sample decode.
fn sharded_case<E: Engine + Send + 'static>(plan: &LayeredPlan, seed: u64, label: &str) {
    let family = LeafFamily::Bernoulli;
    let nv = plan.graph.num_vars;
    let bn = 6;
    let mut rng = Rng::new(seed);
    let params = EinetParams::init(plan, family, seed);
    let x = random_batch(family, bn, nv, &mut rng);
    let mut mask = vec![1.0f32; nv];
    for d in nv / 2..nv {
        mask[d] = 0.0;
    }
    let em = EmConfig { step_size: 0.5, ..Default::default() };

    let mut engine = E::build(plan.clone(), family, bn);
    let mut lp_ref = vec![0.0f32; bn];
    engine.forward(&params, &x, &mask, &mut lp_ref);
    let mut stats_ref = EmStats::zeros_like(&params);
    engine.backward(&params, &x, &mask, bn, &mut stats_ref);
    let mut p_ref = params.clone();
    m_step(&mut p_ref, &stats_ref, &em);
    let mut sample_ref = x.clone();
    engine.decode_batch(
        &params,
        bn,
        &mask,
        DecodeMode::Sample,
        &mut Rng::new(seed + 77),
        &mut sample_ref,
    );

    for shards in [1usize, 4] {
        let ctx = format!("{label} shards={shards}");
        let mut pool =
            ShardedPool::new(boxed_build::<E>, plan, family, &params, shards, bn);
        let mut lp = vec![0.0f32; bn];
        pool.forward(&x, &mask, bn, &mut lp).unwrap();
        for (b, (a, g)) in lp_ref.iter().zip(&lp).enumerate() {
            assert!(
                a.to_bits() == g.to_bits(),
                "{ctx}: forward row {b} diverged: {a} vs {g}"
            );
        }
        let mut stats = EmStats::zeros_like(&params);
        pool.backward(&mut stats).unwrap();
        assert_eq!(stats.loglik, stats_ref.loglik, "{ctx}: loglik");
        let mut p = params.clone();
        m_step(&mut p, &stats, &em);
        assert_eq!(p.data, p_ref.data, "{ctx}: EM-stepped parameters diverged");
        let mut sample_out = x.clone();
        pool.decode(
            bn,
            &mask,
            DecodeMode::Sample,
            &mut Rng::new(seed + 77),
            &mut sample_out,
        )
        .unwrap();
        assert_eq!(sample_ref, sample_out, "{ctx}: Sample decode diverged");
    }
}

#[test]
fn monarch_sharding_parity_in_process() {
    let rat = monarch_plan(LayeredPlan::compile(random_binary_trees(12, 3, 3, 1), 8), 2);
    sharded_case::<DenseEngine>(&rat, 61, "monarch/rat/dense");
    sharded_case::<FusedEngine>(&rat, 61, "monarch/rat/fused");
    let pd = monarch_plan(LayeredPlan::compile(poon_domingos(3, 4, 1, PdAxes::Both), 6), 3);
    sharded_case::<DenseEngine>(&pd, 62, "monarch/pd/dense");
    sharded_case::<SparseEngine>(&pd, 62, "monarch/pd/sparse");
}

#[test]
fn monarch_loopback_tcp_matches_in_process_bitwise() {
    // the v2 handshake carries the weights spec; the worker rebuilds the
    // structured plan and its ParamLayout spans bit-for-bit
    const NV: usize = 16;
    const STRUCTURE: &str = "rat:depth=2,replica=3,seed=5";
    let graph = from_spec(NV, STRUCTURE).expect("structure spec");
    let plan = monarch_plan(LayeredPlan::compile(graph, 8), 2);
    let family = LeafFamily::Bernoulli;
    let params = EinetParams::init(&plan, family, 9);
    let bn = 8;
    let mut rng = Rng::new(2);
    let x = random_batch(family, bn, NV, &mut rng);
    let mut mask = vec![1.0f32; NV];
    for m in mask.iter_mut().skip(NV / 2) {
        *m = 0.0;
    }
    let full = vec![1.0f32; NV];
    let em = EmConfig { step_size: 0.5, ..Default::default() };

    // in-process reference pool
    let mut pool =
        ShardedPool::new(boxed_build::<DenseEngine>, &plan, family, &params, 3, bn);
    let mut lp_ref = vec![0.0f32; bn];
    pool.forward(&x, &mask, bn, &mut lp_ref).unwrap();
    let mut out_ref = x.clone();
    pool.decode(bn, &mask, DecodeMode::Sample, &mut Rng::new(77), &mut out_ref)
        .unwrap();
    let ll_ref = pool.train_step(&x, &full, bn, &em).unwrap();
    let params_ref = pool.params().data.clone();
    pool.stop();

    // loopback-TCP pool over in-thread workers
    let (addrs, handles) = spawn_loopback_workers(3).unwrap();
    let mut tcp = ShardedPool::connect(
        &addrs, STRUCTURE, "dense", &plan, family, &params, 3, bn,
    )
    .expect("connect monarch TCP pool");
    let mut lp = vec![0.0f32; bn];
    tcp.forward(&x, &mask, bn, &mut lp).unwrap();
    for (a, b) in lp_ref.iter().zip(&lp) {
        assert_eq!(a.to_bits(), b.to_bits(), "TCP monarch forward diverged");
    }
    let mut out = x.clone();
    tcp.decode(bn, &mask, DecodeMode::Sample, &mut Rng::new(77), &mut out)
        .unwrap();
    assert_eq!(out_ref, out, "TCP monarch Sample decode diverged");
    let ll = tcp.train_step(&x, &full, bn, &em).unwrap();
    assert_eq!(ll_ref.to_bits(), ll.to_bits(), "TCP monarch EM LL diverged");
    assert_eq!(params_ref, tcp.params().data, "TCP monarch EM update diverged");
    tcp.stop();
    for h in handles {
        h.join().unwrap();
    }
}

// ---------------------------------------------------------------------------
// checkpoints: EINET003 round-trip, EINET002 byte-compat, typed mismatch
// ---------------------------------------------------------------------------

fn tmp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("einet_monarch_{}_{name}", std::process::id()))
}

#[test]
fn monarch_checkpoints_roundtrip_as_einet003() {
    let plan = monarch_plan(LayeredPlan::compile(random_binary_trees(10, 3, 2, 3), 8), 2);
    let params = EinetParams::init(&plan, LeafFamily::Bernoulli, 5);
    let path = tmp("rt.bin");
    params.save(&path).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    assert_eq!(&bytes[..8], b"EINET003", "structured checkpoints use the V3 magic");
    let loaded = EinetParams::load(&path).unwrap();
    assert_eq!(params.layout, loaded.layout);
    assert_eq!(params.data, loaded.data);
    loaded.validate().unwrap();
    let mapped = EinetParams::load_mapped(&path).unwrap();
    assert_eq!(params.layout, mapped.layout);
    assert_eq!(&params.data[..], &mapped.data[..]);
    let _ = std::fs::remove_file(path);
}

#[test]
fn dense_checkpoints_stay_einet002() {
    let plan = LayeredPlan::compile(random_binary_trees(10, 3, 2, 3), 8);
    let params = EinetParams::init(&plan, LeafFamily::Bernoulli, 5);
    let path = tmp("dense.bin");
    params.save(&path).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    assert_eq!(
        &bytes[..8],
        b"EINET002",
        "all-dense checkpoints must stay byte-compatible"
    );
    let loaded = EinetParams::load(&path).unwrap();
    assert_eq!(params.data, loaded.data);
    let _ = std::fs::remove_file(path);
}

#[test]
fn weight_structure_mismatch_is_a_typed_error() {
    let family = LeafFamily::Bernoulli;
    let base = LayeredPlan::compile(random_binary_trees(10, 3, 2, 3), 8);
    let mplan = monarch_plan(base.clone(), 2);
    let dense_layout = ParamLayout::from_plan(&base, family);
    let monarch_layout = ParamLayout::from_plan(&mplan, family);
    // a monarch checkpoint loaded with --weights dense, and vice versa
    for (want, got) in [
        (&dense_layout, &monarch_layout),
        (&monarch_layout, &dense_layout),
    ] {
        let err = want
            .ensure_same_structure(got)
            .expect_err("structure mismatch must be rejected")
            .to_string();
        assert!(
            err.contains("weight-structure mismatch"),
            "typed prefix missing: {err}"
        );
    }
    // matching layouts pass
    monarch_layout.ensure_same_structure(&monarch_layout).unwrap();
    dense_layout.ensure_same_structure(&dense_layout).unwrap();
}

#[test]
fn truncated_and_corrupt_monarch_checkpoints_fail_cleanly() {
    let plan = monarch_plan(LayeredPlan::compile(random_binary_trees(10, 3, 2, 3), 8), 2);
    let params = EinetParams::init(&plan, LeafFamily::Bernoulli, 5);
    let full_path = tmp("full.bin");
    params.save(&full_path).unwrap();
    let full = std::fs::read(&full_path).unwrap();
    let path = tmp("cut.bin");
    // cut inside the magic, the header, the per-level structure tags,
    // and the tensor payload: every prefix must error, never panic
    for cut in [0, 4, 8, 24, 48, 64, full.len() / 2, full.len() - 4] {
        std::fs::write(&path, &full[..cut]).unwrap();
        assert!(
            EinetParams::load(&path).is_err(),
            "truncation at {cut} of {} must fail",
            full.len()
        );
    }
    // corrupt magic
    let mut bad = full.clone();
    bad[7] = b'9';
    std::fs::write(&path, &bad).unwrap();
    assert!(EinetParams::load(&path).is_err(), "corrupt magic must fail");
    let _ = std::fs::remove_file(full_path);
    let _ = std::fs::remove_file(path);
}
