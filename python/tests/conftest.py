"""Shared pytest config: enable float64 once, for the whole suite.

Individual test modules must NOT flip jax.config at import time — import
order would make the setting race between modules.
"""
import jax

jax.config.update("jax_enable_x64", True)
