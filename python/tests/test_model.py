"""L2 model semantics: normalization, marginalization, EM statistics."""

import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.model import Bernoulli, Categorical, EiNet, Gaussian
from compile.structure import layerize, poon_domingos, random_binary_trees

def rat_net(nv=6, depth=2, rep=2, k=3, seed=0, family=None):
    g = random_binary_trees(nv, depth, rep, seed)
    plan = layerize(g, k)
    return EiNet(plan, family or Bernoulli())


class TestForward:
    @given(seed=st.integers(0, 200), nv=st.integers(2, 8),
           depth=st.integers(1, 3), rep=st.integers(1, 3),
           k=st.integers(1, 4))
    @settings(max_examples=15, deadline=None)
    def test_bernoulli_normalizes(self, seed, nv, depth, rep, k):
        """sum_x P(x) == 1 by brute-force enumeration — the defining
        property of a smooth + decomposable PC with normalized weights."""
        net = rat_net(nv, depth, rep, k, seed)
        params = net.init_params(seed)
        allx = jnp.asarray(
            [list(t) for t in itertools.product([0.0, 1.0], repeat=nv)]
        )[:, :, None]
        lp = net.forward(params, allx, jnp.ones(nv))
        total = jax.nn.logsumexp(lp)
        np.testing.assert_allclose(np.exp(total), 1.0, atol=1e-4)

    def test_pallas_and_ref_paths_agree(self):
        g = poon_domingos(3, 4, 1, "hv")
        plan = layerize(g, 3)
        x = jnp.asarray(np.random.default_rng(0).random((4, 12, 1)),
                        dtype=jnp.float32)
        net_p = EiNet(plan, Gaussian(1), use_pallas=True)
        net_r = EiNet(plan, Gaussian(1), use_pallas=False)
        params = net_p.init_params(3)
        np.testing.assert_allclose(
            net_p.forward(params, x, jnp.ones(12)),
            net_r.forward(params, x, jnp.ones(12)), rtol=2e-4, atol=2e-4)

    def test_full_marginalization_is_zero(self):
        net = rat_net()
        params = net.init_params(1)
        x = jnp.zeros((3, 6, 1))
        lp = net.forward(params, x, jnp.zeros(6))
        np.testing.assert_allclose(lp, 0.0, atol=1e-4)

    def test_partial_marginal_equals_enumeration(self):
        """Marginal over X_m computed by the mask equals the brute-force
        sum over X_m's states (Eq. 1 numerator) — decomposability at work."""
        nv = 5
        net = rat_net(nv=nv, depth=2, rep=2, k=3, seed=2)
        params = net.init_params(2)
        rng = np.random.default_rng(0)
        x = rng.integers(0, 2, (4, nv, 1)).astype(np.float32)
        marg = [1, 3]  # marginalize X_1, X_3
        mask = np.ones(nv, np.float32)
        mask[marg] = 0.0
        got = net.forward(jax.tree.map(jnp.asarray, params),
                          jnp.asarray(x), jnp.asarray(mask))
        # brute force: sum over the 4 completions
        acc = np.full(4, -np.inf)
        for v1, v3 in itertools.product([0.0, 1.0], repeat=2):
            xc = x.copy()
            xc[:, marg[0], 0] = v1
            xc[:, marg[1], 0] = v3
            lp = np.asarray(net.forward(params, jnp.asarray(xc),
                                        jnp.ones(nv)))
            acc = np.logaddexp(acc, lp)
        np.testing.assert_allclose(got, acc, rtol=1e-4, atol=1e-4)

    def test_gaussian_density_integrates(self):
        """1-var Gaussian EiNet: compare against quadrature."""
        g = random_binary_trees(2, 1, 1, 0)
        plan = layerize(g, 2)
        net = EiNet(plan, Gaussian(1))
        params = net.init_params(5)
        xs = np.linspace(-3, 4, 1500)
        grid = np.stack(np.meshgrid(xs, xs), -1).reshape(-1, 2, 1)
        lp = []
        for chunk in np.array_split(grid, 30):
            lp.append(np.asarray(net.forward(
                params, jnp.asarray(chunk, dtype=jnp.float32),
                jnp.ones(2))))
        dx = xs[1] - xs[0]
        total = np.exp(np.concatenate(lp)).sum() * dx * dx
        np.testing.assert_allclose(total, 1.0, atol=5e-3)

    def test_categorical_normalizes(self):
        g = random_binary_trees(3, 2, 2, 1)
        plan = layerize(g, 2)
        net = EiNet(plan, Categorical(num_cats=3))
        params = net.init_params(0)
        allx = jnp.asarray([list(t) for t in
                            itertools.product([0., 1., 2.], repeat=3)]
                           )[:, :, None]
        lp = net.forward(params, allx, jnp.ones(3))
        np.testing.assert_allclose(np.exp(jax.nn.logsumexp(lp)), 1.0,
                                   atol=1e-4)


class TestEMStatistics:
    def test_shift_grad_is_leaf_posterior(self):
        """Per variable d: sum_{k,r} p_L == B (total posterior mass of the
        latent mixture assignment at each leaf factor)."""
        net = rat_net(nv=6, depth=2, rep=3, k=4, seed=3)
        params = net.init_params(3)
        b = 7
        x = jnp.asarray(np.random.default_rng(1).integers(0, 2, (b, 6, 1)),
                        dtype=jnp.float32)
        _, grads = net.forward_and_stats(params, x, jnp.ones(6))
        per_var = np.asarray(grads["shift"]).sum(axis=(1, 2))
        np.testing.assert_allclose(per_var, b, rtol=1e-3)

    def test_w_grad_matches_eq6(self):
        """n_{S,N} = w * dlogP/dw identity: grads of logP wrt linear w,
        multiplied by w and renormalized, must be a distribution."""
        net = rat_net(nv=4, depth=2, rep=2, k=3, seed=4)
        params = net.init_params(4)
        x = jnp.asarray(np.random.default_rng(2).integers(0, 2, (5, 4, 1)),
                        dtype=jnp.float32)
        _, grads = net.forward_and_stats(params, x, jnp.ones(4))
        for name in grads:
            if not name.startswith("w"):
                continue
            n = np.asarray(params[name]) * np.asarray(grads[name])
            upd = n / n.sum(axis=(2, 3), keepdims=True)
            np.testing.assert_allclose(
                upd.sum(axis=(2, 3)), 1.0, rtol=1e-4)
            assert (upd >= -1e-7).all()

    def test_em_step_increases_likelihood(self):
        """One full-batch EM step (Eq. 7) must not decrease sum log P."""
        net = rat_net(nv=6, depth=2, rep=2, k=3, seed=5)
        params = net.init_params(5)
        rng = np.random.default_rng(3)
        # correlated data so there is something to learn
        z = rng.integers(0, 2, (64, 1))
        x = ((z + rng.random((64, 6)) * 0.4) > 0.5).astype(np.float32)
        x = jnp.asarray(x[:, :, None])
        mask = jnp.ones(6)

        def em_step(params):
            logp, grads = net.forward_and_stats(params, x, mask)
            new = dict(params)
            for name in params:
                if name.startswith(("w", "mix")):
                    n = params[name] * grads[name]
                    axes = (2, 3) if name.startswith("w") else (1,)
                    den = jnp.sum(n, axis=axes, keepdims=True)
                    new[name] = jnp.where(den > 0, n / den, params[name])
            # bernoulli leaf update: phi = sum p*T / sum p
            p = grads["shift"]
            theta = params["theta"][..., 0]
            phi = jax.nn.sigmoid(theta)
            sum_pt = grads["theta"][..., 0] + phi * p
            new_phi = jnp.where(p > 1e-6,
                                jnp.clip(sum_pt / jnp.maximum(p, 1e-6),
                                         1e-4, 1 - 1e-4),
                                phi)
            new["theta"] = (jnp.log(new_phi)
                            - jnp.log1p(-new_phi))[..., None]
            return float(jnp.sum(logp)), new

        ll0, params = em_step(params)
        ll1, params = em_step(params)
        ll2, _ = em_step(params)
        assert ll1 >= ll0 - 1e-3
        assert ll2 >= ll1 - 1e-3

    def test_marginalized_vars_get_no_stats(self):
        net = rat_net(nv=4, depth=2, rep=2, k=3, seed=6)
        params = net.init_params(6)
        x = jnp.asarray(np.random.default_rng(4).integers(0, 2, (3, 4, 1)),
                        dtype=jnp.float32)
        mask = jnp.asarray([1.0, 0.0, 1.0, 1.0])
        _, grads = net.forward_and_stats(params, x, mask)
        np.testing.assert_allclose(np.asarray(grads["shift"])[1], 0.0,
                                   atol=1e-6)
        np.testing.assert_allclose(np.asarray(grads["theta"])[1], 0.0,
                                   atol=1e-6)
