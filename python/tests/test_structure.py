"""Structure generators + layering invariants (Appendix A / Algorithm 1)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.structure import (layerize, poon_domingos, random_binary_trees)


class TestRandomBinaryTrees:
    @given(nv=st.integers(2, 24), depth=st.integers(1, 4),
           rep=st.integers(1, 5), seed=st.integers(0, 100))
    @settings(max_examples=30, deadline=None)
    def test_invariants(self, nv, depth, rep, seed):
        g = random_binary_trees(nv, depth, rep, seed)
        g.validate()
        root = g.regions[g.root_id]
        assert root.scope == frozenset(range(nv))
        assert len(root.partitions) == rep

    def test_balanced_split(self):
        g = random_binary_trees(16, 1, 1, 0)
        p = g.partitions[0]
        assert len(g.regions[p.left].scope) == 8
        assert len(g.regions[p.right].scope) == 8

    def test_depth_limits_leaf_size(self):
        g = random_binary_trees(16, 4, 2, 3)
        for leaf in g.leaves():
            assert len(leaf.scope) == 1

    def test_deterministic_by_seed(self):
        a = random_binary_trees(12, 3, 2, 42)
        b = random_binary_trees(12, 3, 2, 42)
        assert [r.scope for r in a.regions] == [r.scope for r in b.regions]


class TestPoonDomingos:
    @given(h=st.integers(2, 6), w=st.integers(2, 6), d=st.integers(1, 3))
    @settings(max_examples=20, deadline=None)
    def test_invariants(self, h, w, d):
        g = poon_domingos(h, w, d, "hv")
        g.validate()
        assert g.regions[g.root_id].scope == frozenset(range(h * w))

    def test_vertical_only_gives_column_strips(self):
        g = poon_domingos(4, 8, 2, "v")
        # leaves are width-2 column strips (8/2 = 4 of them)
        leaves = g.leaves()
        assert len(leaves) == 4
        for leaf in leaves:
            cols = {v % 8 for v in leaf.scope}
            assert len(cols) == 2

    def test_region_count_grows_with_inverse_delta(self):
        """Paper: number of sums is O(1/delta^3)."""
        small = poon_domingos(8, 8, 4, "hv")
        big = poon_domingos(8, 8, 2, "hv")
        assert len(big.regions) > len(small.regions)

    def test_multi_partition_regions_exist(self):
        """PD structures exercise the mixing layer."""
        g = poon_domingos(4, 8, 2, "hv")
        assert any(len(r.partitions) > 1 for r in g.regions)


class TestLayerize:
    @given(nv=st.integers(2, 16), depth=st.integers(1, 3),
           rep=st.integers(1, 4), k=st.integers(1, 6),
           seed=st.integers(0, 50))
    @settings(max_examples=25, deadline=None)
    def test_topological_order(self, nv, depth, rep, k, seed):
        """Every einsum input region is produced strictly below its level —
        Algorithm 1's defining property."""
        g = random_binary_trees(nv, depth, rep, seed)
        plan = layerize(g, k)
        produced = set(plan.leaf_region_ids)
        for lv in plan.levels:
            for rid in lv.einsum.left + lv.einsum.right:
                assert rid in produced
            produced |= set(lv.region_out.keys())
        assert g.root_id in produced

    def test_replica_disjointness(self):
        """Leaves sharing a replica index must have disjoint scopes."""
        g = poon_domingos(4, 6, 2, "hv")
        layerize(g, 3)
        by_rep = {}
        for leaf in g.leaves():
            assert leaf.replica >= 0
            occ = by_rep.setdefault(leaf.replica, set())
            assert not (occ & leaf.scope)
            occ |= leaf.scope

    def test_root_is_alone_on_top_level_with_ko1(self):
        g = poon_domingos(4, 4, 2, "hv")
        plan = layerize(g, 5)
        top = plan.levels[-1]
        outs = {g.partitions[p].out for p in top.einsum.partition_ids}
        assert outs == {g.root_id}
        assert top.einsum.ko == 1

    def test_mixing_slots_cover_multi_partition_regions(self):
        g = poon_domingos(4, 6, 2, "hv")
        plan = layerize(g, 3)
        for lv in plan.levels:
            for rid, (kind, slot) in lv.region_out.items():
                nparts = len(g.regions[rid].partitions)
                assert (kind == "m") == (nparts > 1)
            if lv.mixing:
                for ch in lv.mixing.child_slots:
                    assert len(ch) >= 2
                    assert len(ch) <= lv.mixing.cmax

    def test_num_sums_counts_einsum_and_mixing(self):
        g = random_binary_trees(8, 2, 3, 0)
        plan = layerize(g, 4)
        n_e = sum(len(lv.einsum.partition_ids) for lv in plan.levels)
        n_m = sum(len(lv.mixing.region_ids)
                  for lv in plan.levels if lv.mixing)
        assert plan.num_sums == n_e + n_m
