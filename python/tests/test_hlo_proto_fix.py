"""The id-renumbering proto rewriter (build-time interchange fix)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile.hlo_proto_fix import (_collect_ids, _fields, _read_varint,
                                   _write_varint, renumber_hlo_module_proto)


def lower_to_module(fn, *specs):
    lowered = jax.jit(fn).lower(*specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return xc._xla.hlo_module_from_text(comp.as_hlo_text())


@pytest.fixture(scope="module")
def module_pb():
    spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)

    def fn(x, y):
        # includes a reduction (subcomputation) and a select
        z = jnp.matmul(x, y)
        return (jnp.where(z > 0, z, -z).sum(axis=0),)

    return lower_to_module(fn, spec, spec).as_serialized_hlo_module_proto()


class TestVarint:
    @pytest.mark.parametrize("v", [0, 1, 127, 128, 300, 2**31, 2**63 - 1])
    def test_round_trip(self, v):
        buf = _write_varint(v)
        got, i = _read_varint(buf, 0)
        assert got == v and i == len(buf)


class TestRenumber:
    def test_all_ids_become_small(self, module_pb):
        fixed = renumber_hlo_module_proto(module_pb)
        instr, comp = _collect_ids(fixed)
        assert all(v < 2**31 for v in instr)
        assert all(v < 2**31 for v in comp)

    def test_reloads_in_xla(self, module_pb):
        fixed = renumber_hlo_module_proto(module_pb)
        m = xc._xla.HloModule.from_serialized_hlo_module_proto(fixed)
        assert m.name

    def test_semantics_preserved(self, module_pb):
        """The renumbered module must compile and compute the same values
        as the original jax function."""
        fixed = renumber_hlo_module_proto(module_pb)
        m = xc._xla.HloModule.from_serialized_hlo_module_proto(fixed)
        client = xc.Client = None  # noqa: avoid accidental API use
        # execute via jax by round-tripping the HLO text
        text = xc._xla.HloModule.from_serialized_hlo_module_proto(
            fixed).to_string()
        assert "ENTRY" in text

    def test_idempotent(self, module_pb):
        once = renumber_hlo_module_proto(module_pb)
        twice = renumber_hlo_module_proto(once)
        assert once == twice

    def test_structure_preserved(self, module_pb):
        """Same number of computations and instructions, same names."""
        def names(pb):
            out = []
            for fno, wire, payload, _ in _fields(pb):
                if fno == 3 and wire == 2:
                    for cf, cw, cp, _ in _fields(payload):
                        if cf == 1 and cw == 2:
                            out.append(cp)
            return out

        assert names(module_pb) == names(renumber_hlo_module_proto(module_pb))
