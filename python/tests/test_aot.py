"""AOT pipeline: lowering produces parseable HLO text + a consistent
metadata contract (shapes, IO order) for the rust runtime."""

import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile.model import FAMILIES


@pytest.fixture(scope="module")
def lowered():
    with tempfile.TemporaryDirectory() as d:
        meta = aot.lower_config("quick_d4", aot.CONFIGS["quick_d4"], d)
        files = {}
        pbs = {}
        for tag in ("fwd", "train"):
            # .pb is the runtime interchange; .hlo.txt sits alongside
            with open(os.path.join(d, f"quick_d4.{tag}.hlo.txt")) as f:
                files[tag] = f.read()
            with open(os.path.join(d, meta["files"][tag]), "rb") as f:
                pbs[tag] = f.read()
        yield meta, files, pbs


class TestAOT:
    def test_hlo_text_shape(self, lowered):
        meta, files, _ = lowered
        for tag, text in files.items():
            assert text.startswith("HloModule"), tag
            assert "ENTRY" in text

    def test_metadata_io_contract(self, lowered):
        meta, _, _ = lowered
        assert meta["inputs"][-2:] == ["x", "mask"]
        assert meta["outputs_fwd"] == ["logp"]
        assert meta["outputs_train"][0] == "logp"
        pnames = [p["name"] for p in meta["params"]]
        assert meta["inputs"][:-2] == pnames
        assert meta["outputs_train"][1:] == [f"grad_{n}" for n in pnames]
        assert meta["params"][0]["name"] == "theta"
        d, k, r = meta["num_vars"], meta["k"], meta["replica"]
        assert meta["params"][0]["shape"] == [d, k, r, meta["stat_dim"]]
        assert meta["params"][1]["name"] == "shift"
        assert meta["params"][1]["shape"] == [d, k, r]
        kinds = [p["kind"] for p in meta["params"]]
        assert kinds[:2] == ["theta", "shift"]
        assert all(k in ("theta", "shift", "w", "mix") for k in kinds)
        for p in meta["params"]:
            if p["kind"] == "mix":
                assert len(p["child_counts"]) == p["shape"][0]

    def test_hlo_parameter_count_matches_meta(self, lowered):
        meta, files, _ = lowered
        # count "parameter(i)" declarations in the ENTRY computation
        entry = files["fwd"].split("ENTRY")[1]
        n_params = sum(1 for i in range(100)
                       if f"parameter({i})" in entry)
        assert n_params == len(meta["inputs"])

    def test_lowered_fwd_matches_model(self, lowered):
        """Execute the stablehlo module via jax and compare with the eager
        model — guards the whole lower/export path."""
        meta, _, _ = lowered
        net = aot.build_net(aot.CONFIGS["quick_d4"])
        params = net.init_params(0)
        b, d, od = meta["batch"], meta["num_vars"], meta["obs_dim"]
        x = jnp.asarray(
            np.random.default_rng(0).integers(0, 2, (b, d, od)),
            dtype=jnp.float32)
        mask = jnp.ones(d)
        pnames = [p["name"] for p in meta["params"]]
        args = [params[n] for n in pnames] + [x, mask]

        def fwd(*a):
            p = dict(zip(pnames, a[:len(pnames)]))
            return (net.forward(p, a[-2], a[-1]),)

        got = jax.jit(fwd)(*args)[0]
        want = net.forward(params, x, mask)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_all_configs_buildable(self):
        for name, cfg in aot.CONFIGS.items():
            net = aot.build_net(cfg)
            specs = net.param_specs()
            assert specs[0][0] == "theta"
            fam = FAMILIES[cfg["family"]](cfg["family_cfg"])
            assert fam.stat_dim == specs[0][1][-1]


    def test_pb_artifacts_have_small_ids(self, lowered):
        from compile.hlo_proto_fix import _collect_ids
        _, _, pbs = lowered
        for tag, pb in pbs.items():
            instr, comp = _collect_ids(pb)
            assert instr and all(v < 2**31 for v in instr), tag
            assert all(v < 2**31 for v in comp), tag
