"""L1 kernel correctness: Pallas log-einsum-exp / mixing vs the jnp oracle.

This is the CORE correctness signal for the compute hot-spot: forward
values, custom-vjp gradients, numerical stability in the deep-log regime,
and dtype/shape coverage via hypothesis.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import log_einsum_layer, mixing_layer
from compile.kernels import ref

def make_w(rng, l, ko, k, dtype=np.float32, floor=0.01):
    w = rng.random((l, ko, k, k)).astype(dtype) + floor
    return jnp.asarray(w / w.sum(axis=(2, 3), keepdims=True))


def make_mix_w(rng, m, c, nreal=None, dtype=np.float32):
    w = rng.random((m, c)).astype(dtype) + 0.01
    if nreal is not None:
        w[:, nreal:] = 0.0
    return jnp.asarray(w / w.sum(axis=1, keepdims=True))


class TestLogEinsumForward:
    @given(b=st.integers(1, 6), l=st.integers(1, 5), k=st.integers(1, 7),
           ko=st.integers(1, 7), seed=st.integers(0, 10_000))
    @settings(max_examples=40, deadline=None)
    def test_matches_ref(self, b, l, k, ko, seed):
        rng = np.random.default_rng(seed)
        logn = jnp.asarray(rng.normal(size=(b, l, k)) - 2.0)
        lognp = jnp.asarray(rng.normal(size=(b, l, k)) - 2.0)
        w = make_w(rng, l, ko, k, np.float64)
        out = log_einsum_layer(logn, lognp, w)
        want = ref.log_einsum_layer_ref(logn, lognp, w)
        np.testing.assert_allclose(out, want, rtol=1e-9, atol=1e-9)

    @given(seed=st.integers(0, 1000))
    @settings(max_examples=10, deadline=None)
    def test_matches_sparse_style(self, seed):
        """EiNet layout == LibSPN/SPFlow layout, numerically."""
        rng = np.random.default_rng(seed)
        logn = jnp.asarray(rng.normal(size=(3, 4, 5)) - 1.0)
        lognp = jnp.asarray(rng.normal(size=(3, 4, 5)) - 1.0)
        w = make_w(rng, 4, 6, 5, np.float64)
        a = log_einsum_layer(logn, lognp, w)
        b_ = ref.log_einsum_layer_sparse_style(logn, lognp, w)
        np.testing.assert_allclose(a, b_, rtol=1e-8, atol=1e-8)

    def test_float32(self):
        rng = np.random.default_rng(0)
        logn = jnp.asarray(rng.normal(size=(4, 3, 8)).astype(np.float32))
        lognp = jnp.asarray(rng.normal(size=(4, 3, 8)).astype(np.float32))
        w = make_w(rng, 3, 8, 8, np.float32)
        out = log_einsum_layer(logn, lognp, w)
        want = ref.log_einsum_layer_ref(logn, lognp, w)
        assert out.dtype == jnp.float32
        np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-5)

    def test_deep_log_regime_is_finite(self):
        """The whole point of Eq. 4: children with log-probs ~ -1e4 (which
        would underflow any linear-domain computation) stay finite."""
        rng = np.random.default_rng(1)
        logn = jnp.asarray(rng.normal(size=(2, 3, 4)) - 10_000.0)
        lognp = jnp.asarray(rng.normal(size=(2, 3, 4)) - 10_000.0)
        w = make_w(rng, 3, 4, 4, np.float64)
        out = log_einsum_layer(logn, lognp, w)
        assert np.all(np.isfinite(out))
        np.testing.assert_allclose(
            out, ref.log_einsum_layer_ref(logn, lognp, w), rtol=1e-9)
        # the naive variant underflows to -inf on the same input
        naive = ref.log_einsum_layer_naive(logn, lognp, w)
        assert not np.all(np.isfinite(naive))

    def test_convexity_bounds(self):
        """A convex combination of products lies between min and max."""
        rng = np.random.default_rng(2)
        logn = jnp.asarray(rng.normal(size=(5, 2, 6)))
        lognp = jnp.asarray(rng.normal(size=(5, 2, 6)))
        w = make_w(rng, 2, 3, 6, np.float64)
        out = np.asarray(log_einsum_layer(logn, lognp, w))
        logp = np.asarray(logn)[..., :, None] + np.asarray(lognp)[..., None, :]
        lo = logp.min(axis=(-1, -2))[..., None]
        hi = logp.max(axis=(-1, -2))[..., None]
        assert np.all(out >= lo - 1e-9) and np.all(out <= hi + 1e-9)


class TestLogEinsumGrad:
    @given(b=st.integers(1, 4), l=st.integers(1, 4), k=st.integers(1, 5),
           ko=st.integers(1, 5), seed=st.integers(0, 10_000))
    @settings(max_examples=25, deadline=None)
    def test_custom_vjp_matches_autodiff_of_ref(self, b, l, k, ko, seed):
        rng = np.random.default_rng(seed)
        logn = jnp.asarray(rng.normal(size=(b, l, k)) - 1.0)
        lognp = jnp.asarray(rng.normal(size=(b, l, k)) - 1.0)
        w = make_w(rng, l, ko, k, np.float64)
        cot = jnp.asarray(rng.normal(size=(b, l, ko)))

        def scalar(fn):
            return lambda a, b_, c: jnp.sum(fn(a, b_, c) * cot)

        g1 = jax.grad(scalar(log_einsum_layer), argnums=(0, 1, 2))(
            logn, lognp, w)
        g2 = jax.grad(scalar(ref.log_einsum_layer_ref), argnums=(0, 1, 2))(
            logn, lognp, w)
        for a, b_ in zip(g1, g2):
            np.testing.assert_allclose(a, b_, rtol=1e-8, atol=1e-10)

    def test_grad_logn_sums_to_posterior_mass(self):
        """sum_i d logS_k / d logN_i == 1 for every output k (mixture
        responsibilities over the left child sum to one)."""
        rng = np.random.default_rng(3)
        logn = jnp.asarray(rng.normal(size=(1, 1, 5)))
        lognp = jnp.asarray(rng.normal(size=(1, 1, 5)))
        w = make_w(rng, 1, 4, 5, np.float64)
        jac = jax.jacrev(
            lambda a: log_einsum_layer(a, lognp, w)[0, 0])(logn)[:, 0, 0, :]
        np.testing.assert_allclose(jac.sum(axis=-1), 1.0, rtol=1e-9)

    def test_grad_in_deep_log_regime_is_finite(self):
        rng = np.random.default_rng(4)
        logn = jnp.asarray(rng.normal(size=(2, 2, 4)) - 5_000.0)
        lognp = jnp.asarray(rng.normal(size=(2, 2, 4)) - 5_000.0)
        w = make_w(rng, 2, 4, 4, np.float64)
        g = jax.grad(lambda ww: jnp.sum(log_einsum_layer(logn, lognp, ww)))(w)
        assert np.all(np.isfinite(g))


class TestMixing:
    @given(b=st.integers(1, 5), m=st.integers(1, 5), k=st.integers(1, 6),
           c=st.integers(2, 5), seed=st.integers(0, 10_000))
    @settings(max_examples=30, deadline=None)
    def test_matches_ref(self, b, m, k, c, seed):
        rng = np.random.default_rng(seed)
        logc = jnp.asarray(rng.normal(size=(b, m, c, k)) - 2.0)
        w = make_mix_w(rng, m, c, dtype=np.float64)
        out = mixing_layer(logc, w)
        np.testing.assert_allclose(
            out, ref.mixing_layer_ref(logc, w), rtol=1e-9, atol=1e-9)

    @given(seed=st.integers(0, 1000), pad=st.integers(1, 3))
    @settings(max_examples=15, deadline=None)
    def test_padding_is_ignored(self, seed, pad):
        """Zero-weight padded slots must not influence the result, even
        with large-negative padding values."""
        rng = np.random.default_rng(seed)
        b, m, c, k = 3, 2, 3, 4
        logc = rng.normal(size=(b, m, c, k)) - 1.0
        w = make_mix_w(rng, m, c + pad, nreal=c, dtype=np.float64)
        padded = np.concatenate(
            [logc, np.full((b, m, pad, k), -1e30)], axis=2)
        out = mixing_layer(jnp.asarray(padded), w)
        want = ref.mixing_layer_ref(jnp.asarray(logc),
                                    w[:, :c] / w[:, :c].sum(1, keepdims=True))
        np.testing.assert_allclose(out, want, rtol=1e-9, atol=1e-9)

    @given(seed=st.integers(0, 1000))
    @settings(max_examples=15, deadline=None)
    def test_grad_matches_ref(self, seed):
        rng = np.random.default_rng(seed)
        logc = jnp.asarray(rng.normal(size=(2, 3, 4, 5)) - 1.0)
        w = make_mix_w(rng, 3, 4, dtype=np.float64)
        cot = jnp.asarray(rng.normal(size=(2, 3, 5)))
        g1 = jax.grad(lambda a, b_: jnp.sum(mixing_layer(a, b_) * cot),
                      argnums=(0, 1))(logc, w)
        g2 = jax.grad(lambda a, b_: jnp.sum(ref.mixing_layer_ref(a, b_) * cot),
                      argnums=(0, 1))(logc, w)
        for x, y in zip(g1, g2):
            np.testing.assert_allclose(x, y, rtol=1e-8, atol=1e-10)

    def test_single_child_identity(self):
        """C=1 with weight 1 is the identity map."""
        rng = np.random.default_rng(5)
        logc = jnp.asarray(rng.normal(size=(2, 3, 1, 4)))
        w = jnp.ones((3, 1))
        np.testing.assert_allclose(
            mixing_layer(logc, w), logc[:, :, 0, :], rtol=1e-12)
