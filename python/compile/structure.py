"""Region graphs and their layered execution plans (build-time mirror).

The rust side (rust/src/structure/, rust/src/layers/) is the runtime source
of truth for structures used by the pure-rust engines; this module generates
the *same* structures for AOT artifact compilation, so that the HLO
executables bake in the gather patterns while rust only supplies parameters.

Two generators, matching the paper's experiments:

* ``random_binary_trees`` — the RAT-SPN structure (Peharz et al., 2019):
  R replica of randomized balanced binary scope splits down to depth D.
* ``poon_domingos`` — the image-tailored PD structure (Poon & Domingos,
  2011): recursive axis-aligned rectangle splits with step-size delta.

A ``RegionGraph`` is compiled into a ``LayeredPlan`` by the topological
layering of Appendix A (Algorithm 1), phrased over regions/partitions:
every partition becomes one slot of an einsum layer, every region with >= 2
partitions becomes one slot of a mixing layer.
"""

from __future__ import annotations

import random
from dataclasses import dataclass, field


@dataclass
class Region:
    """A scope (set of variables) in the region graph."""
    id: int
    scope: frozenset
    partitions: list = field(default_factory=list)  # partition ids
    replica: int = -1  # leaf regions only: EF replica index

    @property
    def is_leaf(self):
        return not self.partitions


@dataclass
class Partition:
    """A binary decomposition of a region into two disjoint child regions."""
    id: int
    left: int
    right: int
    out: int


class RegionGraph:
    """A vectorized, smooth and decomposable PC skeleton."""

    def __init__(self, num_vars):
        self.num_vars = num_vars
        self.regions: list[Region] = []
        self.partitions: list[Partition] = []
        self._by_scope: dict[frozenset, int] = {}
        self.root_id = self.get_region(frozenset(range(num_vars)))

    def get_region(self, scope) -> int:
        scope = frozenset(scope)
        rid = self._by_scope.get(scope)
        if rid is None:
            rid = len(self.regions)
            self.regions.append(Region(rid, scope))
            self._by_scope[scope] = rid
        return rid

    def add_partition(self, out, left_scope, right_scope) -> int:
        left_scope, right_scope = frozenset(left_scope), frozenset(right_scope)
        assert left_scope and right_scope
        assert not (left_scope & right_scope), "decomposability violated"
        assert left_scope | right_scope == self.regions[out].scope, \
            "smoothness violated"
        lid = self.get_region(left_scope)
        rid = self.get_region(right_scope)
        pid = len(self.partitions)
        self.partitions.append(Partition(pid, lid, rid, out))
        self.regions[out].partitions.append(pid)
        return pid

    # -- structural invariants -------------------------------------------
    def validate(self):
        """Check smoothness + decomposability + acyclicity (depth-bounded)."""
        for p in self.partitions:
            ls = self.regions[p.left].scope
            rs = self.regions[p.right].scope
            assert not (ls & rs)
            assert ls | rs == self.regions[p.out].scope
        assert self.regions[self.root_id].scope == frozenset(
            range(self.num_vars))
        # every region reachable from root must bottom out at leaves
        for r in self.regions:
            assert r.is_leaf or all(
                self.partitions[p].out == r.id for p in r.partitions)

    def leaves(self):
        return [r for r in self.regions if r.is_leaf]

    def assign_replicas(self) -> int:
        """Greedily assign replica indices so leaves sharing a replica have
        pairwise disjoint scopes (Section 3.4).  Returns R."""
        used: list[set] = []
        for r in sorted(self.leaves(), key=lambda r: min(r.scope)):
            for i, occ in enumerate(used):
                if not (occ & r.scope):
                    r.replica = i
                    occ |= r.scope
                    break
            else:
                r.replica = len(used)
                used.append(set(r.scope))
        return len(used)


# ---------------------------------------------------------------------------
# Generators
# ---------------------------------------------------------------------------

def random_binary_trees(num_vars, depth, replica, seed=0) -> RegionGraph:
    """RAT-SPN structure: ``replica`` randomized balanced binary trees of
    scope splits, each of the given ``depth``, mixed at the root."""
    g = RegionGraph(num_vars)
    rng = random.Random(seed)

    def split(scope, d):
        rid = g.get_region(scope)
        if d <= 0 or len(scope) <= 1:
            return rid
        items = sorted(scope)
        rng.shuffle(items)
        half = len(items) // 2
        ls, rs = frozenset(items[:half]), frozenset(items[half:])
        g.add_partition(rid, ls, rs)
        split(ls, d - 1)
        split(rs, d - 1)
        return rid

    for _ in range(replica):
        split(frozenset(range(num_vars)), depth)
    return g


def poon_domingos(height, width, delta, axes="hv") -> RegionGraph:
    """Poon-Domingos structure over an ``height x width`` pixel grid.

    Variables are pixel indices ``row * width + col`` (channels live inside
    the leaf EF).  ``delta`` is the split step-size; candidate cuts fall at
    multiples of delta strictly inside the rectangle.  ``axes`` selects
    horizontal ("h", splits along rows) and/or vertical ("v", along columns)
    cuts; the paper used only vertical splits for its image experiments.
    """
    g = RegionGraph(height * width)

    def scope_of(r0, c0, r1, c1):
        return frozenset(r * width + c
                         for r in range(r0, r1) for c in range(c0, c1))

    seen = set()

    def rec(r0, c0, r1, c1):
        key = (r0, c0, r1, c1)
        if key in seen:
            return
        seen.add(key)
        out = g.get_region(scope_of(r0, c0, r1, c1))
        cuts = []
        if "v" in axes:
            c = c0 + delta
            while c < c1:
                cuts.append(("v", c))
                c += delta
        if "h" in axes:
            r = r0 + delta
            while r < r1:
                cuts.append(("h", r))
                r += delta
        for axis, pos in cuts:
            if axis == "v":
                ls = scope_of(r0, c0, r1, pos)
                rs = scope_of(r0, pos, r1, c1)
            else:
                ls = scope_of(r0, c0, pos, c1)
                rs = scope_of(pos, c0, r1, c1)
            g.add_partition(out, ls, rs)
            if axis == "v":
                rec(r0, c0, r1, pos)
                rec(r0, pos, r1, c1)
            else:
                rec(r0, c0, pos, c1)
                rec(pos, c0, r1, c1)

    rec(0, 0, height, width)
    return g


# ---------------------------------------------------------------------------
# Layered plan (Algorithm 1, phrased over regions/partitions)
# ---------------------------------------------------------------------------

@dataclass
class EinsumLayerSpec:
    """One einsum layer: L partitions computed by a single kernel call."""
    partition_ids: list      # length L
    left: list               # region ids, length L
    right: list              # region ids, length L
    ko: int                  # output vector length of every slot


@dataclass
class MixingLayerSpec:
    """One mixing layer: M regions, each mixing C_m partition slots."""
    region_ids: list         # length M
    child_slots: list        # list of lists of einsum-layer slot indices
    cmax: int


@dataclass
class LevelPlan:
    einsum: EinsumLayerSpec
    mixing: MixingLayerSpec | None
    # region id -> ("e", slot) or ("m", slot): where its output lives
    region_out: dict


@dataclass
class LayeredPlan:
    graph: RegionGraph
    k: int
    num_replica: int
    levels: list            # list of LevelPlan, bottom-up
    leaf_region_ids: list   # evaluation order of leaf regions

    @property
    def num_sums(self):
        """Total number of vectorized sum slots (einsum + mixing)."""
        n = 0
        for lv in self.levels:
            n += len(lv.einsum.partition_ids)
            if lv.mixing:
                n += len(lv.mixing.region_ids)
        return n


def layerize(graph: RegionGraph, k: int) -> LayeredPlan:
    """Compile a region graph into the layered plan of Appendix A.

    Levels are assigned bottom-up: leaves are level 0; a region's level is
    1 + the maximum level over all regions appearing in its partitions; the
    root is bumped to a dedicated top level so its Ko=1 einsum layer never
    shares a kernel call with Ko=K slots.
    """
    graph.validate()
    num_replica = graph.assign_replicas()

    level = {}

    def region_level(rid):
        if rid in level:
            return level[rid]
        r = graph.regions[rid]
        if r.is_leaf:
            level[rid] = 0
        else:
            level[rid] = 1 + max(
                max(region_level(graph.partitions[p].left),
                    region_level(graph.partitions[p].right))
                for p in r.partitions)
        return level[rid]

    for r in graph.regions:
        region_level(r.id)
    top = max(level.values())
    if level[graph.root_id] <= top and any(
            lv == level[graph.root_id] and rid != graph.root_id
            for rid, lv in level.items()):
        level[graph.root_id] = top + 1

    max_level = level[graph.root_id]
    levels = []
    for lv in range(1, max_level + 1):
        rids = [r.id for r in graph.regions
                if level[r.id] == lv and not r.is_leaf]
        if not rids:
            continue
        part_ids, left, right = [], [], []
        slot_of = {}
        for rid in rids:
            for pid in graph.regions[rid].partitions:
                slot_of[pid] = len(part_ids)
                part_ids.append(pid)
                left.append(graph.partitions[pid].left)
                right.append(graph.partitions[pid].right)
        ko = 1 if (len(rids) == 1 and rids[0] == graph.root_id) else k
        espec = EinsumLayerSpec(part_ids, left, right, ko)
        region_out = {}
        mix_rids, mix_children = [], []
        for rid in rids:
            parts = graph.regions[rid].partitions
            if len(parts) == 1:
                region_out[rid] = ("e", slot_of[parts[0]])
            else:
                region_out[rid] = ("m", len(mix_rids))
                mix_rids.append(rid)
                mix_children.append([slot_of[p] for p in parts])
        mspec = None
        if mix_rids:
            cmax = max(len(c) for c in mix_children)
            mspec = MixingLayerSpec(mix_rids, mix_children, cmax)
        levels.append(LevelPlan(espec, mspec, region_out))

    leaf_ids = [r.id for r in sorted(graph.leaves(), key=lambda r: r.id)]
    return LayeredPlan(graph, k, num_replica, levels, leaf_ids)
