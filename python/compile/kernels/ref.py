"""Pure-jnp reference oracles for the EiNet layer operations.

These are the ground truth the Pallas kernels (logeinsumexp.py, mixing.py)
are validated against in python/tests/.  They implement Eq. (4)/(5) of the
paper (the log-einsum-exp trick over a whole einsum layer) and the mixing
layer of Appendix B, in straightforward jax.numpy.

Shapes
------
log_einsum_layer_ref:
    logn  : [B, L, K]   log-densities of the "left" product children
    lognp : [B, L, K]   log-densities of the "right" product children
    w     : [L, Ko, K, K]  linear-domain weights, normalized over (i, j)
    ->      [B, L, Ko]  log-densities of the L vectorized sum nodes

mixing_layer_ref:
    logc  : [B, M, C, K]  log-densities of the (padded) children
    w     : [M, C]        linear-domain mixing weights, normalized over C,
                          exactly 0.0 on padded child slots
    ->      [B, M, K]
"""

from __future__ import annotations

import jax.numpy as jnp


def log_einsum_layer_ref(logn, lognp, w):
    """Eq. (5) with the log-einsum-exp trick of Eq. (4), pure jnp."""
    # max-subtraction per (batch, layer-node) pair
    a = jnp.max(logn, axis=-1, keepdims=True)    # [B, L, 1]
    ap = jnp.max(lognp, axis=-1, keepdims=True)  # [B, L, 1]
    en = jnp.exp(logn - a)                        # [B, L, K], max entry == 1
    enp = jnp.exp(lognp - ap)                     # [B, L, K]
    # S_blk = sum_ij W_lkij N_bli N'_blj
    s = jnp.einsum("bli,blj,lkij->blk", en, enp, w)
    return a + ap + jnp.log(s)


def log_einsum_layer_naive(logn, lognp, w):
    """Eq. (5) WITHOUT max-subtraction — the numerically unstable variant
    used by the stability ablation (A1)."""
    s = jnp.einsum("bli,blj,lkij->blk", jnp.exp(logn), jnp.exp(lognp), w)
    return jnp.log(s)


def log_einsum_layer_sparse_style(logn, lognp, w):
    """The LibSPN/SPFlow-style computation of the same quantity: explicit
    outer-sum product materialization + broadcasted log-sum-exp.

    Computes identical values (up to float error); exists so python tests can
    assert the two layouts agree, mirroring the rust sparse engine."""
    # explicit product nodes: [B, L, K, K] log-domain outer sum
    logp = logn[..., :, None] + lognp[..., None, :]
    # log-sum-exp against log-weights: [B, L, Ko]
    logw = jnp.log(w)  # [L, Ko, K, K]
    z = logw[None] + logp[:, :, None, :, :]  # [B, L, Ko, K, K]
    zmax = jnp.max(z, axis=(-1, -2), keepdims=True)
    out = zmax[..., 0, 0] + jnp.log(
        jnp.sum(jnp.exp(z - zmax), axis=(-1, -2))
    )
    return out


def mixing_layer_ref(logc, w):
    """Appendix B mixing layer: element-wise convex combinations.

    Padded child slots must carry w == 0; their logc values are ignored
    (conventionally filled with a large negative number)."""
    a = jnp.max(logc, axis=2, keepdims=True)  # [B, M, 1, K]
    e = jnp.exp(logc - a)                     # [B, M, C, K]
    s = jnp.einsum("bmck,mc->bmk", e, w)
    return a[:, :, 0, :] + jnp.log(s)
