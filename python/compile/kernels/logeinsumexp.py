"""L1 Pallas kernel: the EiNet einsum layer with the log-einsum-exp trick.

This is the paper's core computational unit (Section 3.2/3.3, Eq. 4/5):

    S_blk = sum_ij  W_lkij * exp(logN_bli) * exp(logN'_blj)

computed stably by subtracting the per-(b, l) maxima of logN / logN' before
exponentiation.  All probabilistic values stay in the log-domain; the weight
tensor stays linear; product nodes are never materialized in HBM (here: the
outer product lives only in the kernel's VMEM scratch).

TPU mapping (see DESIGN.md §Hardware-Adaptation): the grid iterates over the
layer axis `l`; each grid step holds one [B, K] tile of each child plus one
[Ko, K, K] weight slice in VMEM and performs the contraction on the MXU as a
(B, K²) x (K², Ko) matmul after forming the scaled outer product on the VPU.
Interpret mode (mandatory on CPU PJRT) executes the same schedule with numpy.

``pallas_call`` has no automatic reverse-mode rule, so the backward pass is a
second Pallas kernel wired up through ``jax.custom_vjp``.  The backward
quantities (with t_blk = g_blk * exp(a + a' - logS_blk), which is bounded by
1/min_k s_blk and finite whenever all weights are positive):

    gW_lkij  = sum_b t_blk * en_bli * enp_blj
    gN_bli   = en_bli  * sum_k t_blk * (sum_j W_lkij * enp_blj)
    gN'_blj  = enp_blj * sum_k t_blk * (sum_i W_lkij * en_bli)

where en = exp(logN - a), enp = exp(logN' - a').
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fwd_kernel(logn_ref, lognp_ref, w_ref, out_ref):
    """One grid step: the full batch for a single layer-node l."""
    logn = logn_ref[:, 0, :]      # [B, K]
    lognp = lognp_ref[:, 0, :]    # [B, K]
    w = w_ref[0]                  # [Ko, K, K]
    a = jnp.max(logn, axis=-1, keepdims=True)     # [B, 1]
    ap = jnp.max(lognp, axis=-1, keepdims=True)   # [B, 1]
    en = jnp.exp(logn - a)
    enp = jnp.exp(lognp - ap)
    # outer product lives only in kernel scratch; contraction hits the MXU
    # as (B, K*K) @ (K*K, Ko) when lowered for TPU.
    s = jnp.einsum("bi,bj,kij->bk", en, enp, w)
    out_ref[:, 0, :] = a + ap + jnp.log(s)


def _bwd_kernel(logn_ref, lognp_ref, w_ref, logs_ref, g_ref,
                gn_ref, gnp_ref, gw_ref):
    logn = logn_ref[:, 0, :]
    lognp = lognp_ref[:, 0, :]
    w = w_ref[0]                  # [Ko, K, K]
    logs = logs_ref[:, 0, :]      # [B, Ko]
    g = g_ref[:, 0, :]            # [B, Ko]
    a = jnp.max(logn, axis=-1, keepdims=True)
    ap = jnp.max(lognp, axis=-1, keepdims=True)
    en = jnp.exp(logn - a)
    enp = jnp.exp(lognp - ap)
    # t = g / s where s is the scaled linear sum (logS = a + a' + log s)
    t = g * jnp.exp(a + ap - logs)                  # [B, Ko]
    gw_ref[0] = jnp.einsum("bk,bi,bj->kij", t, en, enp)
    gn_ref[:, 0, :] = en * jnp.einsum("bk,kij,bj->bi", t, w, enp)
    gnp_ref[:, 0, :] = enp * jnp.einsum("bk,kij,bi->bj", t, w, en)


def _fwd_call(logn, lognp, w, *, interpret):
    b, l, k = logn.shape
    ko = w.shape[1]
    return pl.pallas_call(
        _fwd_kernel,
        grid=(l,),
        in_specs=[
            pl.BlockSpec((b, 1, k), lambda i: (0, i, 0)),
            pl.BlockSpec((b, 1, k), lambda i: (0, i, 0)),
            pl.BlockSpec((1, ko, k, k), lambda i: (i, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((b, 1, ko), lambda i: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, l, ko), logn.dtype),
        interpret=interpret,
    )(logn, lognp, w)


def _bwd_call(logn, lognp, w, logs, g, *, interpret):
    b, l, k = logn.shape
    ko = w.shape[1]
    return pl.pallas_call(
        _bwd_kernel,
        grid=(l,),
        in_specs=[
            pl.BlockSpec((b, 1, k), lambda i: (0, i, 0)),
            pl.BlockSpec((b, 1, k), lambda i: (0, i, 0)),
            pl.BlockSpec((1, ko, k, k), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((b, 1, ko), lambda i: (0, i, 0)),
            pl.BlockSpec((b, 1, ko), lambda i: (0, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((b, 1, k), lambda i: (0, i, 0)),
            pl.BlockSpec((b, 1, k), lambda i: (0, i, 0)),
            pl.BlockSpec((1, ko, k, k), lambda i: (i, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, l, k), logn.dtype),
            jax.ShapeDtypeStruct((b, l, k), logn.dtype),
            jax.ShapeDtypeStruct(w.shape, w.dtype),
        ],
        interpret=interpret,
    )(logn, lognp, w, logs, g)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def log_einsum_layer(logn, lognp, w, interpret=True):
    """EiNet einsum layer (Eq. 5), numerically stable, Pallas-backed.

    Args:
      logn:  [B, L, K]  left-child log-densities.
      lognp: [B, L, K]  right-child log-densities.
      w:     [L, Ko, K, K] linear sum-weights, normalized over (i, j),
             strictly positive (the paper's stability condition).
      interpret: run the Pallas kernel in interpret mode (required on CPU).

    Returns:
      [B, L, Ko] log-densities of the vectorized sum nodes.
    """
    return _fwd_call(logn, lognp, w, interpret=interpret)


def _vjp_fwd(logn, lognp, w, interpret):
    logs = _fwd_call(logn, lognp, w, interpret=interpret)
    return logs, (logn, lognp, w, logs)


def _vjp_bwd(interpret, res, g):
    logn, lognp, w, logs = res
    gn, gnp, gw = _bwd_call(logn, lognp, w, logs, g, interpret=interpret)
    return gn, gnp, gw


log_einsum_layer.defvjp(_vjp_fwd, _vjp_bwd)
