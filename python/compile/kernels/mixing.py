"""L1 Pallas kernel: the EiNet mixing layer (Appendix B).

Sum nodes with C > 1 product children are over-parameterized into a chain of
(einsum layer -> element-wise mixture).  The mixture is

    S_bmk = sum_c  w_mc * exp(logC_bmck)

over a zero-padded [B, M, C, K] tensor of child log-densities, where padded
slots carry w_mc == 0 (their logC values, conventionally a large negative
number, are never exponentiated into anything that matters because the max
is taken over real children only when at least one weight is positive —
guaranteed since every mixing node has >= 2 real children).

Same custom_vjp treatment as logeinsumexp.py; backward quantities with
t_bmk = g_bmk * exp(a_bmk - logS_bmk):

    gW_mc    = sum_bk t_bmk * e_bmck
    gC_bmck  = w_mc * t_bmk * e_bmck

where e = exp(logC - a), a = max_c logC.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fwd_kernel(logc_ref, w_ref, out_ref):
    logc = logc_ref[:, 0, :, :]   # [B, C, K]
    w = w_ref[0]                  # [C]
    a = jnp.max(logc, axis=1, keepdims=True)   # [B, 1, K]
    e = jnp.exp(logc - a)                      # [B, C, K]
    s = jnp.einsum("bck,c->bk", e, w)
    out_ref[:, 0, :] = a[:, 0, :] + jnp.log(s)


def _bwd_kernel(logc_ref, w_ref, logs_ref, g_ref, gc_ref, gw_ref):
    logc = logc_ref[:, 0, :, :]   # [B, C, K]
    w = w_ref[0]                  # [C]
    logs = logs_ref[:, 0, :]      # [B, K]
    g = g_ref[:, 0, :]            # [B, K]
    a = jnp.max(logc, axis=1, keepdims=True)
    e = jnp.exp(logc - a)
    t = g * jnp.exp(a[:, 0, :] - logs)          # [B, K]
    gw_ref[0] = jnp.einsum("bk,bck->c", t, e)
    gc_ref[:, 0, :, :] = w[None, :, None] * t[:, None, :] * e


def _fwd_call(logc, w, *, interpret):
    b, m, c, k = logc.shape
    return pl.pallas_call(
        _fwd_kernel,
        grid=(m,),
        in_specs=[
            pl.BlockSpec((b, 1, c, k), lambda i: (0, i, 0, 0)),
            pl.BlockSpec((1, c), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((b, 1, k), lambda i: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, m, k), logc.dtype),
        interpret=interpret,
    )(logc, w)


def _bwd_call(logc, w, logs, g, *, interpret):
    b, m, c, k = logc.shape
    return pl.pallas_call(
        _bwd_kernel,
        grid=(m,),
        in_specs=[
            pl.BlockSpec((b, 1, c, k), lambda i: (0, i, 0, 0)),
            pl.BlockSpec((1, c), lambda i: (i, 0)),
            pl.BlockSpec((b, 1, k), lambda i: (0, i, 0)),
            pl.BlockSpec((b, 1, k), lambda i: (0, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((b, 1, c, k), lambda i: (0, i, 0, 0)),
            pl.BlockSpec((1, c), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(logc.shape, logc.dtype),
            jax.ShapeDtypeStruct(w.shape, w.dtype),
        ],
        interpret=interpret,
    )(logc, w, logs, g)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def mixing_layer(logc, w, interpret=True):
    """EiNet mixing layer (Appendix B), numerically stable, Pallas-backed.

    Args:
      logc: [B, M, C, K] padded child log-densities.
      w:    [M, C] linear mixing weights, normalized over C, exactly 0 on
            padded slots.
      interpret: run the Pallas kernel in interpret mode (required on CPU).

    Returns:
      [B, M, K] mixed log-densities.
    """
    return _fwd_call(logc, w, interpret=interpret)


def _vjp_fwd(logc, w, interpret):
    logs = _fwd_call(logc, w, interpret=interpret)
    return logs, (logc, w, logs)


def _vjp_bwd(interpret, res, g):
    logc, w, logs = res
    gc, gw = _bwd_call(logc, w, logs, g, interpret=interpret)
    return gc, gw


mixing_layer.defvjp(_vjp_fwd, _vjp_bwd)
