# L1: Pallas kernels for the paper's compute hot-spot (einsum + mixing
# layers with the log-einsum-exp trick), plus the pure-jnp oracle (ref.py).
from .logeinsumexp import log_einsum_layer
from .mixing import mixing_layer

__all__ = ["log_einsum_layer", "mixing_layer"]
