"""L2: the EiNet model — jax forward/backward over a layered plan.

The forward pass evaluates a smooth + decomposable PC bottom-up:

  1. exponential-family input layer: a [B, D, K, R] tensor E of per-variable
     log-densities (Section 3.4), parameterized by *natural* parameters so
     that EM's expected statistics pop out of jax.grad (Section 3.5);
  2. leaf regions: factorizations over E (segment-sums over scopes);
  3. alternating einsum layers (Pallas kernel, Eq. 5) and mixing layers
     (Pallas kernel, Appendix B) following the LayeredPlan;
  4. the root sum yields log P(x) per sample.

Marginalization (Eq. 1's integrals) is a per-variable 0/1 mask that zeroes
the corresponding E rows — decomposability then guarantees the feedforward
pass computes the exact marginal.

EM statistics via autodiff (the paper's algorithmic contribution):
  d log P / d W      (linear-domain sum weights)  = n_{S,N} of Eq. 6
  d log P / d shift  (zero-valued offset on E)    = p_L    of Eq. 6
  d log P / d theta  (natural leaf params)        = p_L * (T(x) - phi)
so a single jax.vjp call yields everything the M-step (Eq. 7-9) needs; the
M-step itself lives in rust (rust/src/em/).
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import log_einsum_layer, mixing_layer
from .kernels import ref as kref
from .structure import LayeredPlan

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Exponential families (natural parameterization)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Bernoulli:
    """Bernoulli over a binary variable: T(x)=x, A(t)=log(1+e^t)."""
    name: str = "bernoulli"
    obs_dim: int = 1
    stat_dim: int = 1

    def log_prob(self, theta, x):
        """theta: [D,K,R,1]; x: [B,D,1] -> [B,D,K,R]."""
        t = theta[..., 0]                                   # [D,K,R]
        a = jnp.logaddexp(0.0, t)                           # log(1+e^t)
        return x[:, :, None, None, 0] * t[None] - a[None]

    def init_theta(self, key, d, k, r):
        p = jax.random.uniform(key, (d, k, r, 1), minval=0.2, maxval=0.8)
        return jnp.log(p) - jnp.log1p(-p)


@dataclass(frozen=True)
class Gaussian:
    """Diagonal Gaussian over ``channels`` observation channels per variable.

    Natural params per channel: t1 = mu/var, t2 = -1/(2 var);
    T(x) = (x, x^2); A = sum_ch -t1^2/(4 t2) - log(-2 t2)/2.
    """
    channels: int = 1
    name: str = "gaussian"

    @property
    def obs_dim(self):
        return self.channels

    @property
    def stat_dim(self):
        return 2 * self.channels

    def log_prob(self, theta, x):
        """theta: [D,K,R,2*CH]; x: [B,D,CH] -> [B,D,K,R]."""
        ch = self.channels
        t1 = theta[..., :ch]                                # [D,K,R,CH]
        t2 = theta[..., ch:]                                # [D,K,R,CH]
        a = -t1 * t1 / (4.0 * t2) - 0.5 * jnp.log(-2.0 * t2)
        xb = x[:, :, None, None, :]                         # [B,D,1,1,CH]
        lp = (xb * t1[None] + xb * xb * t2[None]
              - a[None] - 0.5 * math.log(2.0 * math.pi))
        return jnp.sum(lp, axis=-1)

    def init_theta(self, key, d, k, r):
        ch = self.channels
        kmu, _ = jax.random.split(key)
        mu = 0.5 + 0.15 * jax.random.normal(kmu, (d, k, r, ch))
        var = jnp.full((d, k, r, ch), 0.05)
        return jnp.concatenate([mu / var, -0.5 / var], axis=-1)


@dataclass(frozen=True)
class Categorical:
    """Categorical over ``num_cats`` values: theta = logits, T(x) = one-hot."""
    num_cats: int = 2
    name: str = "categorical"
    obs_dim: int = 1

    @property
    def stat_dim(self):
        return self.num_cats

    def log_prob(self, theta, x):
        """theta: [D,K,R,V]; x: [B,D,1] integer-valued -> [B,D,K,R]."""
        logz = jax.nn.logsumexp(theta, axis=-1)             # [D,K,R]
        onehot = jax.nn.one_hot(x[..., 0].astype(jnp.int32), self.num_cats)
        lp = jnp.einsum("bdv,dkrv->bdkr", onehot, theta)
        return lp - logz[None]

    def init_theta(self, key, d, k, r):
        return 0.1 * jax.random.normal(key, (d, k, r, self.num_cats))


FAMILIES = {
    "bernoulli": lambda cfg: Bernoulli(),
    "gaussian": lambda cfg: Gaussian(channels=cfg.get("channels", 1)),
    "categorical": lambda cfg: Categorical(num_cats=cfg.get("num_cats", 2)),
}


# ---------------------------------------------------------------------------
# The EiNet
# ---------------------------------------------------------------------------

class EiNet:
    """A layered EiNet over a ``LayeredPlan``.

    Parameters (a flat dict, the artifact IO contract — see aot.py):
      theta          [D, K, R, S]    natural leaf parameters
      shift          [D, K, R]       zero offset on E (its grad is p_L)
      w{i}           [L_i, Ko_i, K, K]  per-level einsum weights (linear)
      mix{i}         [M_i, C_i]      per-level mixing weights (linear)
    """

    def __init__(self, plan: LayeredPlan, family, use_pallas=True):
        self.plan = plan
        self.family = family
        self.use_pallas = use_pallas
        self.k = plan.k
        self.num_vars = plan.graph.num_vars
        self.num_replica = plan.num_replica
        self._build_leaf_index()

    def _build_leaf_index(self):
        """Flatten (leaf region, var) pairs for one segment-sum gather."""
        var_idx, rep_idx, seg_idx = [], [], []
        for seg, rid in enumerate(self.plan.leaf_region_ids):
            r = self.plan.graph.regions[rid]
            for v in sorted(r.scope):
                var_idx.append(v)
                rep_idx.append(r.replica)
                seg_idx.append(seg)
        self.leaf_var = np.array(var_idx, dtype=np.int32)
        self.leaf_rep = np.array(rep_idx, dtype=np.int32)
        self.leaf_seg = np.array(seg_idx, dtype=np.int32)
        self.num_leaves = len(self.plan.leaf_region_ids)

    # -- parameters -------------------------------------------------------
    def param_specs(self):
        """Deterministic (name, shape) list — the artifact IO contract."""
        d, k, r = self.num_vars, self.k, self.num_replica
        specs = [("theta", (d, k, r, self.family.stat_dim)),
                 ("shift", (d, k, r))]
        for i, lv in enumerate(self.plan.levels):
            l = len(lv.einsum.partition_ids)
            specs.append((f"w{i}", (l, lv.einsum.ko, k, k)))
            if lv.mixing is not None:
                m = len(lv.mixing.region_ids)
                specs.append((f"mix{i}", (m, lv.mixing.cmax)))
        return specs

    def init_params(self, seed=0):
        key = jax.random.PRNGKey(seed)
        d, k, r = self.num_vars, self.k, self.num_replica
        params = {}
        key, sub = jax.random.split(key)
        params["theta"] = self.family.init_theta(sub, d, k, r)
        params["shift"] = jnp.zeros((d, k, r))
        for i, lv in enumerate(self.plan.levels):
            l = len(lv.einsum.partition_ids)
            key, sub = jax.random.split(key)
            w = jax.random.uniform(sub, (l, lv.einsum.ko, k, k),
                                   minval=0.01, maxval=1.0)
            params[f"w{i}"] = w / jnp.sum(w, axis=(2, 3), keepdims=True)
            if lv.mixing is not None:
                m = len(lv.mixing.region_ids)
                key, sub = jax.random.split(key)
                wm = jax.random.uniform(sub, (m, lv.mixing.cmax),
                                        minval=0.01, maxval=1.0)
                pad = np.zeros((m, lv.mixing.cmax), dtype=np.float32)
                for j, ch in enumerate(lv.mixing.child_slots):
                    pad[j, :len(ch)] = 1.0
                wm = wm * pad
                params[f"mix{i}"] = wm / jnp.sum(wm, axis=1, keepdims=True)
        return params

    # -- forward ----------------------------------------------------------
    def leaf_log_densities(self, params, x, marg_mask):
        """[B, NumLeaves, K] leaf-region log-densities."""
        e = self.family.log_prob(params["theta"], x)        # [B,D,K,R]
        e = e + params["shift"][None]
        e = e * marg_mask[None, :, None, None]
        # gather (var, replica) pairs then segment-sum into leaf regions
        gathered = e[:, self.leaf_var, :, self.leaf_rep]    # [T,B,K]
        seg = jax.ops.segment_sum(gathered, jnp.asarray(self.leaf_seg),
                                  num_segments=self.num_leaves)
        return jnp.transpose(seg, (1, 0, 2))                # [B,NL,K]

    def forward(self, params, x, marg_mask):
        """log P(x) under the marginalization mask -> [B]."""
        leaf_lp = self.leaf_log_densities(params, x, marg_mask)
        b = x.shape[0]
        out = {}  # region id -> [B, K_region]
        for seg, rid in enumerate(self.plan.leaf_region_ids):
            out[rid] = leaf_lp[:, seg, :]
        for i, lv in enumerate(self.plan.levels):
            logn = jnp.stack([out[r] for r in lv.einsum.left], axis=1)
            lognp = jnp.stack([out[r] for r in lv.einsum.right], axis=1)
            if self.use_pallas:
                es = log_einsum_layer(logn, lognp, params[f"w{i}"])
            else:
                es = kref.log_einsum_layer_ref(logn, lognp, params[f"w{i}"])
            ms = None
            if lv.mixing is not None:
                m, cmax = len(lv.mixing.region_ids), lv.mixing.cmax
                cols = []
                for j, ch in enumerate(lv.mixing.child_slots):
                    idx = list(ch) + [0] * (cmax - len(ch))
                    cols.append(es[:, idx, :])
                logc = jnp.stack(cols, axis=1)              # [B,M,C,K]
                pad = np.full((m, cmax), NEG_INF, dtype=np.float32)
                for j, ch in enumerate(lv.mixing.child_slots):
                    pad[j, :len(ch)] = 0.0
                logc = logc + pad[None, :, :, None]
                if self.use_pallas:
                    ms = mixing_layer(logc, params[f"mix{i}"])
                else:
                    ms = kref.mixing_layer_ref(logc, params[f"mix{i}"])
            for rid, (kind, slot) in lv.region_out.items():
                out[rid] = es[:, slot, :] if kind == "e" else ms[:, slot, :]
        root = out[self.plan.graph.root_id]                 # [B, 1]
        return root[:, 0]

    # -- EM statistics ----------------------------------------------------
    def forward_and_stats(self, params, x, marg_mask):
        """Per-sample log-likelihoods + summed expected EM statistics.

        Returns (logp [B], grads dict matching param_specs order): grads of
        sum_b log P(x_b) w.r.t. every parameter tensor — exactly the E-step
        accumulators of Eq. 6/7 (see module docstring).
        """
        logp, pullback = jax.vjp(
            lambda p: self.forward(p, x, marg_mask), params)
        grads = pullback(jnp.ones_like(logp))[0]
        return logp, grads
