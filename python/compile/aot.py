"""AOT compilation: lower EiNet entry points to HLO *text* artifacts.

Emits, per configuration:
  artifacts/<name>.fwd.hlo.txt    logp(params..., x, mask)          -> (logp,)
  artifacts/<name>.train.hlo.txt  logp + EM expected statistics     -> (logp, grads...)
  artifacts/<name>.meta.json      IO contract the rust runtime reads

HLO text — NOT serialized HloModuleProto — is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the published `xla` 0.1.6 crate) rejects; the text
parser reassigns ids and round-trips cleanly.  See /opt/xla-example/.

Python runs only here, at build time.  The rust binary owns the parameters,
feeds them as executable inputs, and performs the EM M-step — so no
re-lowering ever happens during training.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .hlo_proto_fix import renumber_hlo_module_proto
from .model import FAMILIES, EiNet
from .structure import layerize, poon_domingos, random_binary_trees

# ---------------------------------------------------------------------------
# Configurations compiled by `make artifacts`
# ---------------------------------------------------------------------------
CONFIGS = {
    # tiny config exercised by pytest and rust integration tests
    "quick_d4": dict(
        structure="rat", num_vars=4, depth=2, replica=2, k=4, seed=7,
        family="bernoulli", family_cfg={}, batch=8,
    ),
    # binary density estimation (Table-1-like workloads)
    "rat_bin_d16": dict(
        structure="rat", num_vars=16, depth=3, replica=4, k=8, seed=1,
        family="bernoulli", family_cfg={}, batch=64,
    ),
    # image modeling with the PD structure (Fig-4-like workloads);
    # 8x8 grayscale, vertical+horizontal splits with delta=2
    "pd_img_8x8": dict(
        structure="pd", height=8, width=8, delta=2, axes="hv", k=8,
        family="gaussian", family_cfg={"channels": 1}, batch=32,
    ),
}


def build_net(cfg):
    if cfg["structure"] == "rat":
        g = random_binary_trees(cfg["num_vars"], cfg["depth"],
                                cfg["replica"], cfg["seed"])
    elif cfg["structure"] == "pd":
        g = poon_domingos(cfg["height"], cfg["width"], cfg["delta"],
                          cfg["axes"])
    else:
        raise ValueError(cfg["structure"])
    plan = layerize(g, cfg["k"])
    family = FAMILIES[cfg["family"]](cfg["family_cfg"])
    return EiNet(plan, family)


def param_descriptors(net, specs):
    """Describe each parameter tensor for the rust runtime: name, shape,
    kind, and (for mixing layers) the per-row real-child counts needed by
    the M-step's padding-aware renormalization."""
    out = []
    for name, shape in specs:
        desc = {"name": name, "shape": list(shape)}
        if name == "theta":
            desc["kind"] = "theta"
        elif name == "shift":
            desc["kind"] = "shift"
        elif name.startswith("mix"):
            desc["kind"] = "mix"
            level = int(name[3:])
            desc["child_counts"] = [
                len(ch) for ch in net.plan.levels[level].mixing.child_slots
            ]
        else:
            desc["kind"] = "w"
        out.append(desc)
    return out


def to_xla_computation(lowered):
    mlir_mod = lowered.compiler_ir("stablehlo")
    return xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )


def lower_config(name, cfg, out_dir):
    net = build_net(cfg)
    specs = net.param_specs()
    pnames = [n for n, _ in specs]
    batch = cfg["batch"]
    d, od = net.num_vars, net.family.obs_dim

    def fwd(*args):
        params = dict(zip(pnames, args[:len(pnames)]))
        x, mask = args[len(pnames)], args[len(pnames) + 1]
        return (net.forward(params, x, mask),)

    def train(*args):
        params = dict(zip(pnames, args[:len(pnames)]))
        x, mask = args[len(pnames)], args[len(pnames) + 1]
        logp, grads = net.forward_and_stats(params, x, mask)
        return (logp,) + tuple(grads[n] for n in pnames)

    arg_specs = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in specs]
    arg_specs.append(jax.ShapeDtypeStruct((batch, d, od), jnp.float32))
    arg_specs.append(jax.ShapeDtypeStruct((d,), jnp.float32))

    paths = {}
    for tag, fn in (("fwd", fwd), ("train", train)):
        lowered = jax.jit(fn).lower(*arg_specs)
        comp = to_xla_computation(lowered)
        # keep HLO text for humans / debugging ...
        txt_path = os.path.join(out_dir, f"{name}.{tag}.hlo.txt")
        with open(txt_path, "w") as f:
            f.write(comp.as_hlo_text())
        # ... but the rust runtime consumes BINARY protos with renumbered
        # ids, taken straight from the XlaComputation. NEVER round-trip
        # through hlo_module_from_text here: the HLO text parser (both in
        # xla_extension 0.5.1 and in current jaxlib) keeps process-global
        # state and silently corrupts the second-or-later large module
        # parsed in one process. See hlo_proto_fix.py.
        fixed = renumber_hlo_module_proto(
            comp.as_serialized_hlo_module_proto())
        pb_path = os.path.join(out_dir, f"{name}.{tag}.pb")
        with open(pb_path, "wb") as f:
            f.write(fixed)
        paths[tag] = os.path.basename(pb_path)
        print(f"  {pb_path}: {len(fixed)} bytes pb")

    meta = {
        "name": name,
        "config": {k: v for k, v in cfg.items()},
        "family": cfg["family"],
        "family_cfg": cfg["family_cfg"],
        "num_vars": d,
        "obs_dim": od,
        "stat_dim": net.family.stat_dim,
        "k": net.k,
        "replica": net.num_replica,
        "batch": batch,
        "params": param_descriptors(net, specs),
        "inputs": pnames + ["x", "mask"],
        "outputs_fwd": ["logp"],
        "outputs_train": ["logp"] + [f"grad_{n}" for n in pnames],
        "files": paths,
        "num_levels": len(net.plan.levels),
        "num_sums": net.plan.num_sums,
        "num_leaves": net.num_leaves,
    }
    with open(os.path.join(out_dir, f"{name}.meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    return meta


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="comma-separated config names")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    names = args.only.split(",") if args.only else list(CONFIGS)
    for name in names:
        print(f"[aot] lowering {name} ...")
        lower_config(name, CONFIGS[name], args.out_dir)
    # manifest for artifact discovery on the rust side
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump({"configs": names}, f)
    print("[aot] done")


if __name__ == "__main__":
    main()
