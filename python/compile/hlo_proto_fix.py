"""Renumber HLO proto ids so xla_extension 0.5.1 accepts binary protos.

Why this exists (see DESIGN.md §6 and the README gotchas):

* jax >= 0.5 / modern XLA assign 64-bit unique ids to HLO instructions and
  computations (module_id << 32 | local_id). xla_extension 0.5.1 —the
  version behind the published `xla` 0.1.6 crate — RET_CHECKs
  `proto.id() <= INT_MAX` and rejects them.
* The workaround of exchanging HLO *text* (whose parser reassigns small
  ids) turned out to be unsound: the 0.5.1 text parser keeps process-global
  state and silently corrupts the second large module parsed in a process
  (observed as the marginalization mask being constant-folded away).
* Binary protobuf parsing, by contrast, is stateless. So we renumber the
  ids *here*, at build time, operating directly on the protobuf wire
  format (no hlo_pb2 schema is shipped with jaxlib), and emit `.pb`
  artifacts the rust runtime loads with `HloModuleProto::parse_proto`.

Field numbers (stable in xla/service/hlo.proto across the relevant
versions):

  HloModuleProto:      name=1, entry_computation_name=2, computations=3,
                       host_program_shape=4, id=5, entry_computation_id=6
  HloComputationProto: name=1, instructions=2, program_shape=4, id=5,
                       root_id=6
  HloInstructionProto: id=35, operand_ids=36, control_predecessor_ids=37,
                       called_computation_ids=38

Instruction ids and computation ids live in separate spaces; each is
remapped densely from 0 within the module.
"""

from __future__ import annotations


def _read_varint(buf: bytes, i: int) -> tuple[int, int]:
    shift = 0
    val = 0
    while True:
        b = buf[i]
        val |= (b & 0x7F) << shift
        i += 1
        if not b & 0x80:
            return val, i
        shift += 7


def _write_varint(val: int) -> bytes:
    out = bytearray()
    while True:
        b = val & 0x7F
        val >>= 7
        if val:
            out.append(b | 0x80)
        else:
            out.append(b)
            return bytes(out)


def _fields(buf: bytes):
    """Yield (field_no, wire_type, payload, raw_bytes) for a message."""
    i = 0
    n = len(buf)
    while i < n:
        tag, j = _read_varint(buf, i)
        field_no = tag >> 3
        wire = tag & 7
        if wire == 0:  # varint
            val, k = _read_varint(buf, j)
            yield field_no, wire, val, buf[i:k]
            i = k
        elif wire == 1:  # fixed64
            yield field_no, wire, buf[j:j + 8], buf[i:j + 8]
            i = j + 8
        elif wire == 2:  # length-delimited
            ln, k = _read_varint(buf, j)
            yield field_no, wire, buf[k:k + ln], buf[i:k + ln]
            i = k + ln
        elif wire == 5:  # fixed32
            yield field_no, wire, buf[j:j + 4], buf[i:j + 4]
            i = j + 4
        else:
            raise ValueError(f"unsupported wire type {wire}")


def _emit(field_no: int, wire: int, payload) -> bytes:
    tag = _write_varint((field_no << 3) | wire)
    if wire == 0:
        return tag + _write_varint(payload)
    if wire == 2:
        return tag + _write_varint(len(payload)) + payload
    return tag + payload


def _packed_varints(payload: bytes):
    i = 0
    while i < len(payload):
        v, i = _read_varint(payload, i)
        yield v


def _collect_ids(module: bytes) -> tuple[dict, dict]:
    # proto3 omits zero-valued fields: an instruction/computation with
    # id == 0 serializes no id field at all, but references to it still
    # appear — seed both maps with the identity for 0.
    instr_map: dict[int, int] = {0: 0}
    comp_map: dict[int, int] = {0: 0}
    for fno, wire, payload, _ in _fields(module):
        if fno == 3 and wire == 2:  # computation
            for cf, cw, cp, _ in _fields(payload):
                if cf == 5 and cw == 0 and cp not in comp_map:
                    comp_map[cp] = len(comp_map)
                elif cf == 2 and cw == 2:  # instruction
                    for inf, inw, inp, _ in _fields(cp):
                        if inf == 35 and inw == 0 and inp not in instr_map:
                            instr_map[inp] = len(instr_map)
    return instr_map, comp_map


def _rewrite_instruction(buf: bytes, instr_map: dict, comp_map: dict) -> bytes:
    out = bytearray()
    for fno, wire, payload, raw in _fields(buf):
        if fno == 35 and wire == 0:
            out += _emit(35, 0, instr_map[payload])
        elif fno in (36, 37) and wire == 0:
            out += _emit(fno, 0, instr_map[payload])
        elif fno in (36, 37) and wire == 2:  # packed
            packed = b"".join(
                _write_varint(instr_map[v]) for v in _packed_varints(payload)
            )
            out += _emit(fno, 2, packed)
        elif fno == 38 and wire == 0:
            out += _emit(38, 0, comp_map[payload])
        elif fno == 38 and wire == 2:
            packed = b"".join(
                _write_varint(comp_map[v]) for v in _packed_varints(payload)
            )
            out += _emit(fno, 2, packed)
        else:
            out += raw
    return bytes(out)


def _rewrite_computation(buf: bytes, instr_map: dict, comp_map: dict) -> bytes:
    out = bytearray()
    for fno, wire, payload, raw in _fields(buf):
        if fno == 2 and wire == 2:
            out += _emit(2, 2, _rewrite_instruction(payload, instr_map, comp_map))
        elif fno == 5 and wire == 0:
            out += _emit(5, 0, comp_map[payload])
        elif fno == 6 and wire == 0:
            out += _emit(6, 0, instr_map[payload])
        else:
            out += raw
    return bytes(out)


def renumber_hlo_module_proto(module: bytes) -> bytes:
    """Return the module proto with instruction/computation ids remapped
    densely from 0 (all < 2^31), preserving everything else."""
    instr_map, comp_map = _collect_ids(module)
    out = bytearray()
    for fno, wire, payload, raw in _fields(module):
        if fno == 3 and wire == 2:
            out += _emit(3, 2, _rewrite_computation(payload, instr_map, comp_map))
        elif fno == 5 and wire == 0:
            out += _emit(5, 0, 0)  # module id: single module per file
        elif fno == 6 and wire == 0:
            out += _emit(6, 0, comp_map[payload])
        else:
            out += raw
    return bytes(out)
