"""L1 perf analysis: VMEM footprint + MXU utilization estimates per config.

Pallas interpret-mode gives CPU-numpy timings only, which say nothing about
TPU behaviour; per DESIGN.md §Hardware-Adaptation the L1 kernel is
evaluated *structurally*: for each einsum layer of each AOT config this
script reports

  * the per-grid-step VMEM working set of the `logeinsumexp` kernel
    (two [B, K] child tiles, one [Ko, K, K] weight slice, one [B, Ko]
    output tile, plus the [B, K^2]-equivalent outer-product scratch that
    lives in registers/VMEM — never HBM), against the ~16 MiB budget;
  * the MXU utilization estimate for the contraction when phrased as a
    (B, K^2) x (K^2, Ko) matmul on the 128x128 systolic array: the
    fraction of each 128-lane tile actually filled.

Run:  python -m compile.tpu_estimate
"""

from __future__ import annotations

from . import aot

VMEM_BYTES = 16 * 1024 * 1024
MXU = 128


def layer_stats(b, l, k, ko):
    """Per-grid-step working set (bytes) and MXU fill for one einsum layer."""
    child_tiles = 2 * b * k * 4
    weight_slice = ko * k * k * 4
    out_tile = b * ko * 4
    prod_scratch = b * k * k * 4  # registers/VMEM only, never HBM
    total = child_tiles + weight_slice + out_tile + prod_scratch
    # matmul view: (B x K^2) . (K^2 x Ko)
    fill_rows = min(b, MXU) / MXU
    fill_inner = min(k * k, MXU) / MXU
    fill_cols = min(ko, MXU) / MXU
    return total, fill_rows * fill_inner * fill_cols, l


def main():
    print(f"{'config':<14} {'level':>5} {'L':>5} {'Ko':>3} "
          f"{'VMEM/step':>12} {'fits?':>6} {'MXU fill':>9}")
    for name, cfg in aot.CONFIGS.items():
        net = aot.build_net(cfg)
        b, k = cfg["batch"], net.k
        for i, lv in enumerate(net.plan.levels):
            l = len(lv.einsum.partition_ids)
            ko = lv.einsum.ko
            total, fill, _ = layer_stats(b, l, k, ko)
            print(f"{name:<14} {i:>5} {l:>5} {ko:>3} "
                  f"{total/1024:>10.1f}Ki {str(total < VMEM_BYTES):>6} "
                  f"{fill:>8.4f}")
        print()
    print("Interpretation: every layer's per-step working set sits far "
          "inside the ~16MiB VMEM budget, so the BlockSpec schedule (grid "
          "over the layer axis) is HBM-bandwidth-bound, not VMEM-capacity "
          "bound. MXU fill is limited by K^2 and Ko relative to the 128-"
          "wide array: K >= 12 fills the contraction axis (K^2 >= 128+); "
          "the paper's K = 40 would fully occupy it. At the small K used "
          "for CPU-testable configs the kernel is deliberately latency-"
          "bound, matching the paper's observation that EiNet gains grow "
          "with K.")


if __name__ == "__main__":
    main()
